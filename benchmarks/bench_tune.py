"""Autotuning benchmark / CI smoke lane.

The saxpy-chain workload compiled two ways:

  default — the untuned reference schedule (`compile_fortran` defaults);
  tuned   — `tune="search"` over a fresh on-disk `TuningStore`: every
            candidate schedule (VMEM block depth, dataflow vs chained,
            donation) is compiled, verified bit-identical to the
            reference, and timed; the winner is persisted.

Phases:

  cold — fresh store: the search runs (`tune_trials > 0`,
         `tuned_kernels > 0`), results must be bit-identical to the
         default schedule, and the tuned program must not be slower
         (speedup >= 1.0 — the search may legitimately keep the
         reference schedule);
  warm — a *fresh process* (re-executed through the shared
         `common.reexec_lane` helper) over the same store: the tuned
         schedule applies with `tune_cache_hits > 0` and
         `tune_trials == 0` — the persistence claim of the subsystem.

Writes ``BENCH_tune.json`` with both phases; ``--smoke`` asserts the
gates so CI fails on a tuning regression instead of letting it rot.

    PYTHONPATH=src python -m benchmarks.run tune
    PYTHONPATH=src python -m benchmarks.run --smoke tune
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import numpy as np

try:
    from .common import emit, reexec_lane, write_json_atomic
except ImportError:  # standalone: python benchmarks/bench_tune.py
    from common import emit, reexec_lane, write_json_atomic

from repro.core import compile_fortran
from repro.core.runtime import DeviceDataEnvironment
from repro.core.tune import TuningStore
from repro.core.workloads import chain_source

_WARM_JSON = "BENCH_tune_warm.json"


def _bench(prog, args_fn, iters: int) -> float:
    times = []
    for _ in range(iters + 1):  # first pass warms the jit caches
        a = args_fn()
        t0 = time.perf_counter()
        prog.run("chain", args=a)
        times.append(time.perf_counter() - t0)
    return float(np.median(times[1:]))


def _args_fn(stages: int, n: int):
    rng = np.random.default_rng(0)
    bufs = [rng.normal(size=n).astype(np.float32) for _ in range(stages + 1)]

    def args_fn():
        return tuple([np.int32(n)] + [b.copy() for b in bufs])

    return args_fn


def _tuned_program(src: str, store_path: str, budget: int):
    return compile_fortran(
        src, tune="search", tune_store=store_path,
        tune_trial_budget=budget, tune_seed=0,
    )


def warm_check(store_path: str, stages: int, n: int, budget: int) -> None:
    """The warm phase, run in a fresh process: same store, no search."""
    src = chain_source(stages, n)
    env = DeviceDataEnvironment()
    prog = _tuned_program(src, store_path, budget)
    prog.run("chain", args=_args_fn(stages, n)(), env=env)
    s = env.stats
    write_json_atomic(
        _WARM_JSON,
        {
            "tune_trials": s.tune_trials,
            "tune_cache_hits": s.tune_cache_hits,
            "tune_cache_misses": s.tune_cache_misses,
            "tuned_kernels": s.tuned_kernels,
        },
    )


def run(smoke: bool = False, store_path: str = None) -> Dict[str, float]:
    stages = 4 if smoke else 6
    n = 4096 if smoke else 8192
    iters = 3 if smoke else 5
    budget = 8 if smoke else 16
    store_path = store_path or os.path.abspath(".tune_bench_store.json")
    if os.path.exists(store_path):  # cold phase: a genuinely fresh store
        os.remove(store_path)

    src = chain_source(stages, n)
    args_fn = _args_fn(stages, n)

    default = compile_fortran(src)
    env = DeviceDataEnvironment()
    tuned = _tuned_program(src, store_path, budget)

    # cold run: triggers the search, persists the winner
    out_t = tuned.run("chain", args=args_fn(), env=env)
    out_d = default.run("chain", args=args_fn())
    for j in range(stages + 1):
        assert np.array_equal(
            np.asarray(out_t[f"s{j}"]), np.asarray(out_d[f"s{j}"])
        ), f"tuned schedule changed s{j}"
    cold = {
        "tune_trials": env.stats.tune_trials,
        "tune_cache_hits": env.stats.tune_cache_hits,
        "tune_cache_misses": env.stats.tune_cache_misses,
        "tuned_kernels": env.stats.tuned_kernels,
    }
    entries = TuningStore(store_path).items()
    schedule = next(iter(entries.values()))["schedule"] if entries else None

    t_default = _bench(default, args_fn, iters)
    t_tuned = _bench(tuned, args_fn, iters)
    retries = 3
    while smoke and t_tuned > t_default and retries > 0:
        # the gate is the speedup sign; absorb shared-runner noise (the
        # search already proved the winner no slower than the reference
        # on its own measurements) before declaring a regression
        t_default = min(t_default, _bench(default, args_fn, iters))
        t_tuned = min(t_tuned, _bench(tuned, args_fn, iters))
        retries -= 1
    speedup = t_default / max(t_tuned, 1e-12)

    # warm phase: a fresh process over the same store must apply the
    # tuned schedule without a single search trial
    if os.path.exists(_WARM_JSON):
        os.remove(_WARM_JSON)
    reexec_lane(
        "benchmarks.bench_tune",
        args=[
            "--warm-check", "--store", store_path,
            "--stages", str(stages), "--n", str(n), "--budget", str(budget),
        ],
    )
    with open(_WARM_JSON) as f:
        warm = json.load(f)
    os.remove(_WARM_JSON)

    emit("tune/default_schedule", t_default * 1e6, f"stages={stages} n={n}")
    emit(
        "tune/searched",
        t_tuned * 1e6,
        f"speedup_vs_default={speedup:.2f}x trials={cold['tune_trials']} "
        f"schedule={json.dumps(schedule, sort_keys=True) if schedule else '-'}",
    )
    emit(
        "tune/warm_process", 0.0,
        f"cache_hits={warm['tune_cache_hits']} trials={warm['tune_trials']}",
    )

    result = {
        "workload": "saxpy-chain",
        "stages": stages,
        "n": n,
        "default_us": t_default * 1e6,
        "tuned_us": t_tuned * 1e6,
        "speedup_vs_default": speedup,
        "schedule": schedule,
        "cold": cold,
        "warm": warm,
    }
    if smoke:
        write_json_atomic("BENCH_tune.json", result)
        assert cold["tune_trials"] > 0, result
        assert cold["tuned_kernels"] > 0, result
        assert warm["tune_cache_hits"] > 0, result
        assert warm["tune_trials"] == 0, (
            "warm process re-searched instead of hitting the store", result
        )
        assert warm["tuned_kernels"] > 0, result
        assert speedup >= 1.0, (
            f"tuned schedule slower than default: {speedup:.2f}x"
        )
        print(
            f"# smoke ok: tuned {speedup:.2f}x vs default after "
            f"{cold['tune_trials']} trials; warm process hit the store "
            f"with 0 trials -> BENCH_tune.json"
        )
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--no-header", action="store_true")
    ap.add_argument("--warm-check", action="store_true")
    ap.add_argument("--store", default=None)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--budget", type=int, default=8)
    args = ap.parse_args()
    if args.warm_check:
        warm_check(args.store, args.stages, args.n, args.budget)
        return
    if not args.no_header:
        print("name,us_per_call,derived")
    res = run(smoke=args.smoke, store_path=args.store)
    if not args.smoke:
        print(
            f"# tuned schedule {res['speedup_vs_default']:.2f}x vs default "
            f"({res['cold']['tune_trials']} search trials, winner "
            f"{json.dumps(res['schedule'], sort_keys=True)})"
        )


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    main()
