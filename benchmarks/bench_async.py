"""Sync-vs-async kernel dispatch through the stream/event scheduler.

Measures wall clock for a chain of saxpy kernels dispatched two ways:

  sync   — the paper's original create/launch/wait triple: every launch
           is immediately fenced (one kernel in flight at a time);
  async  — the nowait model: all launches issued back to back on
           round-robin streams, one wait_all at the end.

Two workloads: ``independent`` (k kernels on k disjoint buffer pairs —
the schedule the DAG can fully overlap) and ``dependent`` (a serial
RAW chain through one buffer — overlap impossible, checks ordering is
preserved and overhead is not worse than sync).

    PYTHONPATH=src python benchmarks/bench_async.py [--n 1048576]
        [--kernels 8] [--streams 4] [--iters 5]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import emit

import sys

from repro.core.runtime import DeviceDataEnvironment, KernelHandle
from repro.core.schedule import AsyncScheduler


def _saxpy_fn():
    @jax.jit
    def fn(a, x, y):
        return a, x, y + a * x

    return fn


def _make_handles(env: DeviceDataEnvironment, fn, k: int, n: int,
                  dependent: bool):
    """k saxpy handles: disjoint (x_i, y_i) pairs when independent, or a
    true RAW chain accumulating into one shared y buffer when dependent."""
    handles = []
    if dependent:
        env.alloc("y", (n,), np.float32)
        env.dma_h2d(np.zeros(n, np.float32), "y")
    for i in range(k):
        env.alloc(f"x{i}", (n,), np.float32)
        env.dma_h2d(np.full(n, 1.0 + i, np.float32), f"x{i}")
        yname = "y" if dependent else f"y{i}"
        if not dependent:
            env.alloc(yname, (n,), np.float32)
            env.dma_h2d(np.zeros(n, np.float32), yname)
        handles.append(
            KernelHandle(
                f"saxpy_{i}",
                fn,
                (jnp.float32(2.0), env.lookup(f"x{i}"), env.lookup(yname)),
            )
        )
    return handles


def _run_schedule(env, fn, k, n, n_streams, mode: str, dependent: bool):
    """One timed pass; returns (seconds, scheduler summary)."""
    sched = AsyncScheduler(env=env, n_streams=n_streams)
    if dependent:
        rw = [({f"x{i}", "y"}, {"y"}) for i in range(k)]
    else:
        rw = [({f"x{i}"}, {f"y{i}"}) for i in range(k)]
    handles = _make_handles(env, fn, k, n, dependent)
    t0 = time.perf_counter()
    events = []
    for h, (reads, writes) in zip(handles, rw):
        ev = sched.launch(h, reads=reads, writes=writes,
                          nowait=(mode == "async"))
        if mode == "sync":
            sched.wait_event(ev)
        else:
            events.append(ev)
    for ev in events:
        sched.wait_event(ev)
    dt = time.perf_counter() - t0
    return dt, sched.summary()


def bench(mode: str, k: int, n: int, n_streams: int, iters: int,
          dependent: bool = False):
    fn = _saxpy_fn()
    times = []
    summary = None
    env = None
    for _ in range(iters + 1):  # first pass is warmup (jit compile)
        env = DeviceDataEnvironment()
        dt, summary = _run_schedule(env, fn, k, n, n_streams, mode, dependent)
        times.append(dt)
    # correctness of the last pass: y accumulates 2*(1+i) per chained
    # kernel; independent kernels each hold 2*(1+i)
    if dependent:
        expect = sum(2.0 * (1.0 + i) for i in range(k))
        got = float(np.asarray(env.lookup("y").array)[0])
    else:
        expect = 2.0 * k  # kernel k-1: x = k
        got = float(np.asarray(env.lookup(f"y{k - 1}").array)[0])
    assert abs(got - expect) < 1e-3, (mode, dependent, got, expect)
    return float(np.median(times[1:])), summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 20)
    ap.add_argument("--kernels", type=int, default=8)
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()

    t_sync, _ = bench("sync", args.kernels, args.n, args.streams, args.iters)
    t_async, s = bench("async", args.kernels, args.n, args.streams,
                       args.iters)
    ratio = t_async / t_sync if t_sync > 0 else float("inf")
    emit("async_sched/independent_sync", t_sync * 1e6,
         f"kernels={args.kernels}")
    emit("async_sched/independent_async", t_async * 1e6,
         f"speedup={t_sync / max(t_async, 1e-12):.2f}x "
         f"streams_used={s['streams_used']} overlap={s['max_overlap']}")

    d_sync, _ = bench("sync", args.kernels, args.n, args.streams, args.iters,
                      dependent=True)
    d_async, sd = bench("async", args.kernels, args.n, args.streams,
                        args.iters, dependent=True)
    emit("async_sched/dependent_sync", d_sync * 1e6,
         f"kernels={args.kernels}")
    emit("async_sched/dependent_async", d_async * 1e6,
         f"waves={sd['waves']} edges={sd['edges']}")

    print(f"# async/sync wall-clock ratio (independent): {ratio:.3f} "
          f"({'async no slower' if ratio <= 1.05 else 'async slower'})")
    if ratio > 1.05:
        sys.exit(1)


if __name__ == "__main__":
    main()
