"""Benchmark harness — one module per paper table.

    PYTHONPATH=src python -m benchmarks.run [table1 table2 resources loc roofline fusion]
    PYTHONPATH=src python -m benchmarks.run --smoke

Each benchmark prints ``name,us_per_call,derived`` CSV rows.

``--smoke`` is the CI perf lane: the fusion + dataflow benchmarks on
tiny shapes, asserting the speedup signs (fused faster than unfused,
single-call dataflow faster than the chained schedule, 100% compile
cache hits, ``dataflow_kernels``/``hbm_round_trips_eliminated`` > 0)
and emitting ``BENCH_fusion.json`` + ``BENCH_dataflow.json`` so perf
regressions fail the build instead of rotting silently.
"""

from __future__ import annotations

import sys


def main() -> None:
    argv = sys.argv[1:]
    if "--smoke" in argv:
        from . import bench_dataflow, bench_fusion
        print("name,us_per_call,derived")
        bench_fusion.run(smoke=True)  # asserts + writes BENCH_fusion.json
        bench_dataflow.run(smoke=True)  # asserts + BENCH_dataflow.json
        return
    which = set(argv) or {"table1", "table2", "resources", "loc",
                          "roofline", "fusion", "dataflow"}
    print("name,us_per_call,derived")
    if "table1" in which:
        from . import bench_saxpy
        bench_saxpy.run()
    if "table2" in which:
        from . import bench_sgesl
        bench_sgesl.run()
    if "resources" in which:
        from . import bench_resources
        bench_resources.run()
    if "loc" in which:
        from . import bench_loc
        bench_loc.run()
    if "roofline" in which:
        from . import bench_roofline
        bench_roofline.run()
    if "fusion" in which:
        from . import bench_fusion
        bench_fusion.run()
    if "dataflow" in which:
        from . import bench_dataflow
        bench_dataflow.run()


if __name__ == "__main__":
    main()
