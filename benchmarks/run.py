"""Benchmark harness — one module per paper table.

    PYTHONPATH=src python -m benchmarks.run [table1 table2 resources loc
                                             roofline fusion dataflow
                                             teams tune obs chaos analyze
                                             sentry]
    PYTHONPATH=src python -m benchmarks.run --smoke [fusion dataflow
                                                     teams tune obs chaos
                                                     analyze sentry]

Each benchmark prints ``name,us_per_call,derived`` CSV rows.

``--smoke`` is the CI perf lane.  Every smoke lane runs as a subprocess
through the shared :func:`benchmarks.common.reexec_lane` helper (one
re-exec/env recipe instead of one per lane), because several lanes need
state jax only reads at process start:

  fusion   — gates fused-vs-unfused speedup + 100% compile-cache hits;
             emits ``BENCH_fusion.json``;
  dataflow — gates ``dataflow_kernels``/``hbm_round_trips_eliminated``
             > 0, one ``pallas_call`` per fused region, and the speedup
             sign vs the chained schedule; emits ``BENCH_dataflow.json``;
  teams    — re-executed under
             ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
             (the flag must precede jax init); gates
             ``teams_kernels``/``sharded_allocs``/
             ``device_pinned_launches`` > 0 and bit-identical
             teams-vs-single results; emits ``BENCH_teams.json``;
  tune     — cold-run schedule search over a fresh persistent store
             (``tune_trials > 0``, ``tuned_kernels > 0``, tuned ≥
             default throughput) plus a warm *fresh-process* pass over
             the same store (``tune_cache_hits > 0`` with
             ``tune_trials == 0``); emits ``BENCH_tune.json``;
  obs      — traced fused teams-chain workload over 4 forced host
             devices: validates the exported Chrome-trace JSON (sorted
             complete events, one track per stream and per device),
             gates the Prometheus render (strict parse, latency
             p50/p95/p99, live TransferStats counters), and asserts the
             *disabled* tracer costs < 1% of the saxpy-chain launch-plan
             replay; emits ``BENCH_obs.json`` + ``repro_trace_obs.json``;
  chaos    — scripted fault plan over 4 forced host devices: gates
             bit-identical results under injected DMA + launch faults
             with device 1 quarantined (``launch_retries > 0``,
             ``quarantined_devices == 1``, ``degraded_launches > 0``),
             bounds recovery latency from the traced recovery span
             intervals, and asserts the *disabled* resilience engine
             costs < 1% of the launch-plan replay; emits
             ``BENCH_chaos.json`` + ``repro_trace_chaos.json``;
  analyze  — static-analyzer gates: seeded defect fixtures (nowait RAW
             race, lost-update, VMEM blow-up) each produce exactly
             their diagnostic code and the depend-fixed variant is
             clean, the shipped corpus (workloads + examples) analyzes
             strict-clean, and ``analyze="warn"`` costs < 5% extra
             compile time; emits ``BENCH_analyze.json``;
  sentry   — trace-analytics regression sentry over 4 forced host
             devices: analyzes traced saxpy-chain + teams runs (gates
             critical-path ids resolving into the trace, phase
             breakdown summing to wall time, ≥ 1 roofline-classified
             kernel window), records baselines into a workspace-local
             ``BaselineStore``, then re-runs the chain under an
             injected ``dma_h2d`` latency fault and requires
             ``compare()`` to attribute the slowdown to the *DMA
             phase*; emits ``BENCH_sentry.json`` +
             ``repro_trace_sentry.json`` + ``BENCH_sentry_report.txt``
             and refreshes ``BENCH_trajectory.json``.

Plain ``--smoke`` (no lane names) runs the fusion + dataflow pair, the
original fast lane.
"""

from __future__ import annotations

import sys

from .common import reexec_lane

#: lane name -> (module, extra reexec kwargs)
_SMOKE_LANES = {
    "fusion": ("benchmarks.bench_fusion", {}),
    "dataflow": ("benchmarks.bench_dataflow", {}),
    "teams": ("benchmarks.bench_teams", {"force_host_devices": 4}),
    "tune": ("benchmarks.bench_tune", {}),
    "obs": ("benchmarks.bench_obs", {"force_host_devices": 4}),
    "chaos": ("benchmarks.bench_chaos", {"force_host_devices": 4}),
    "analyze": ("benchmarks.bench_analyze", {}),
    "sentry": ("benchmarks.bench_sentry", {"force_host_devices": 4}),
}


def _run_lane(name: str, smoke: bool) -> None:
    module, kwargs = _SMOKE_LANES[name]
    args = ["--no-header"] + (["--smoke"] if smoke else [])
    reexec_lane(module, args=args, **kwargs)


def main() -> None:
    argv = sys.argv[1:]
    if "--smoke" in argv:
        named = [a for a in argv if a != "--smoke"]
        unknown = [a for a in named if a not in _SMOKE_LANES]
        if unknown:
            raise SystemExit(f"unknown smoke lane(s): {unknown}")
        lanes = [l for l in _SMOKE_LANES if l in named] or [
            "fusion", "dataflow"
        ]
        print("name,us_per_call,derived")
        for lane in lanes:
            _run_lane(lane, smoke=True)
        return
    which = set(argv) or {"table1", "table2", "resources", "loc",
                          "roofline", "fusion", "dataflow", "teams",
                          "tune", "obs", "chaos", "analyze", "sentry"}
    print("name,us_per_call,derived")
    if "table1" in which:
        from . import bench_saxpy
        bench_saxpy.run()
    if "table2" in which:
        from . import bench_sgesl
        bench_sgesl.run()
    if "resources" in which:
        from . import bench_resources
        bench_resources.run()
    if "loc" in which:
        from . import bench_loc
        bench_loc.run()
    if "roofline" in which:
        from . import bench_roofline
        bench_roofline.run()
    if "fusion" in which:
        from . import bench_fusion
        bench_fusion.run()
    if "dataflow" in which:
        from . import bench_dataflow
        bench_dataflow.run()
    if "teams" in which:
        _run_lane("teams", smoke=False)
    if "tune" in which:
        _run_lane("tune", smoke=False)
    if "obs" in which:
        _run_lane("obs", smoke=False)
    if "chaos" in which:
        _run_lane("chaos", smoke=False)
    if "analyze" in which:
        _run_lane("analyze", smoke=False)
    if "sentry" in which:
        _run_lane("sentry", smoke=False)


if __name__ == "__main__":
    main()
