"""Benchmark harness — one module per paper table.

    PYTHONPATH=src python -m benchmarks.run [table1 table2 resources loc roofline]

Each benchmark prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import sys


def main() -> None:
    which = set(sys.argv[1:]) or {"table1", "table2", "resources", "loc",
                                  "roofline"}
    print("name,us_per_call,derived")
    if "table1" in which:
        from . import bench_saxpy
        bench_saxpy.run()
    if "table2" in which:
        from . import bench_sgesl
        bench_sgesl.run()
    if "resources" in which:
        from . import bench_resources
        bench_resources.run()
    if "loc" in which:
        from . import bench_loc
        bench_loc.run()
    if "roofline" in which:
        from . import bench_roofline
        bench_roofline.run()


if __name__ == "__main__":
    main()
