"""Benchmark harness — one module per paper table.

    PYTHONPATH=src python -m benchmarks.run [table1 table2 resources loc
                                             roofline fusion dataflow teams]
    PYTHONPATH=src python -m benchmarks.run --smoke [teams]

Each benchmark prints ``name,us_per_call,derived`` CSV rows.

``--smoke`` is the CI perf lane: the fusion + dataflow benchmarks on
tiny shapes, asserting the speedup signs (fused faster than unfused,
single-call dataflow faster than the chained schedule, 100% compile
cache hits, ``dataflow_kernels``/``hbm_round_trips_eliminated`` > 0)
and emitting ``BENCH_fusion.json`` + ``BENCH_dataflow.json`` so perf
regressions fail the build instead of rotting silently.

``--smoke teams`` is the multi-device lane: it re-executes
``bench_teams`` in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the flag must
be set before jax initialises, so it cannot be applied in-process),
gating on ``teams_kernels > 0``, ``sharded_allocs > 0``,
``device_pinned_launches > 0`` and bit-identical teams-vs-single
results, and emitting ``BENCH_teams.json``.
"""

from __future__ import annotations

import os
import subprocess
import sys

_FORCE_DEVICES = "--xla_force_host_platform_device_count=4"


def _run_teams(smoke: bool, header: bool) -> None:
    """Run bench_teams in a subprocess with a forced multi-device host
    platform (jax reads XLA_FLAGS at import, so the current process may
    already be pinned to one device).  ``header=False`` when this
    process already printed the shared CSV header."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (flags + " " + _FORCE_DEVICES).strip()
    argv = [sys.executable, "-m", "benchmarks.bench_teams"]
    if smoke:
        argv.append("--smoke")
    if not header:
        argv.append("--no-header")
    sys.stdout.flush()
    proc = subprocess.run(argv, env=env)
    if proc.returncode != 0:
        raise SystemExit(proc.returncode)


def main() -> None:
    argv = sys.argv[1:]
    if "--smoke" in argv:
        rest = {a for a in argv if a != "--smoke"}
        if rest == {"teams"}:
            # asserts + writes BENCH_teams.json
            _run_teams(smoke=True, header=True)
            return
        from . import bench_dataflow, bench_fusion
        print("name,us_per_call,derived")
        bench_fusion.run(smoke=True)  # asserts + writes BENCH_fusion.json
        bench_dataflow.run(smoke=True)  # asserts + BENCH_dataflow.json
        if "teams" in rest:
            _run_teams(smoke=True, header=False)
        return
    which = set(argv) or {"table1", "table2", "resources", "loc",
                          "roofline", "fusion", "dataflow", "teams"}
    print("name,us_per_call,derived")
    if "table1" in which:
        from . import bench_saxpy
        bench_saxpy.run()
    if "table2" in which:
        from . import bench_sgesl
        bench_sgesl.run()
    if "resources" in which:
        from . import bench_resources
        bench_resources.run()
    if "loc" in which:
        from . import bench_loc
        bench_loc.run()
    if "roofline" in which:
        from . import bench_roofline
        bench_roofline.run()
    if "fusion" in which:
        from . import bench_fusion
        bench_fusion.run()
    if "dataflow" in which:
        from . import bench_dataflow
        bench_dataflow.run()
    if "teams" in which:
        _run_teams(smoke=False, header=False)


if __name__ == "__main__":
    main()
