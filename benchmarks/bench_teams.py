"""Multi-device teams-distribute benchmark / CI smoke lane.

Three workloads, each compiled three ways:

  single — ``target parallel do``: one kernel, one device;
  mesh   — ``target teams distribute parallel do`` with the default
           single-dispatch launch: ONE jitted ``shard_map`` over the
           canonical ``teams`` device mesh, each shard running the
           per-team kernel on its contiguous row slice (reductions go
           through the chunked league-invariant combine);
  loop   — the same directive with ``teams_mesh=False``: the per-team
           ``pallas_call`` loop (one host dispatch per team).

Results must be bit-identical across all three for elementwise
workloads; the teams reduction is bitwise *league-invariant* (mesh vs
loop vs league-1 all fold the same fixed chunk layout).

Speedup claims are attributed with trace evidence, not bare wall-clock:
the traced mesh run's per-device *kernel-window* spans (cat ``team``,
track ``dev<n>``) all share one dispatch window, so their pairwise
overlap across device tracks is structural proof of single-dispatch
execution — under the per-team loop the team slices are disjoint host
dispatch records and the overlap is zero.  The smoke lane gates on
``mesh_launches > 0``, ``collective_reductions > 0``, overlap > 0, and
parity; the span intervals are embedded in ``BENCH_teams.json`` and the
full timeline is written to ``repro_trace_teams.json``.

Run under a forced multi-device host platform:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.bench_teams [--smoke]

or let the harness set the flag for you:

    PYTHONPATH=src python -m benchmarks.run --smoke teams
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Tuple

import numpy as np

try:
    from .common import emit, percentiles, write_json_atomic
except ImportError:  # standalone: python benchmarks/bench_teams.py
    from common import emit, percentiles, write_json_atomic

import jax

from repro.core import compile_fortran
from repro.core.obs.analytics import normalize_spans, overlap_matrix
from repro.core.runtime import DeviceDataEnvironment
from repro.core.workloads import (
    chain_with_reduction_source,
    saxpy_teams_source,
    teams_chain_source,
)

_TRACE_JSON = "repro_trace_teams.json"


def _bench(prog, name: str, args_fn, iters: int):
    times = []
    for _ in range(iters + 1):  # first pass warms the jit caches
        a = args_fn()
        t0 = time.perf_counter()
        prog.run(name, args=a)
        times.append(time.perf_counter() - t0)
    warmed = times[1:]
    return float(np.median(warmed)), warmed


def _team_windows(tracer) -> List[Dict[str, Any]]:
    """The traced per-device kernel-window slices of every mesh launch:
    one ``(device_track, start_us, end_us)`` interval per team span."""
    spans = normalize_spans(tracer)
    t0 = spans[0].ts if spans else 0.0
    return [
        {
            "device": s.track,
            "team": s.args.get("team"),
            "kernel": s.args.get("kernel"),
            "start_us": (s.ts - t0) * 1e6,
            "end_us": (s.end - t0) * 1e6,
        }
        for s in spans
        if s.cat == "team" and s.args.get("mesh")
    ]


def _parity(a, b) -> bool:
    return bool(np.array_equal(np.asarray(a), np.asarray(b)))


def run(smoke: bool = False) -> Dict[str, Any]:
    n_dev = len(jax.devices())
    n = 4096 if smoke else 65536
    iters = 3 if smoke else 5
    rng = np.random.default_rng(0)

    result: Dict[str, Any] = {"n": n, "devices": n_dev, "workloads": {}}

    # -- workload sources -------------------------------------------------
    x = rng.normal(size=n).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    cbufs = [rng.normal(size=n).astype(np.float32) for _ in range(4)]
    rbufs = [rng.normal(size=n).astype(np.float32) for _ in range(3)]

    workloads: List[Tuple[str, str, str, Any]] = [
        (
            "saxpy",
            saxpy_teams_source(n),
            saxpy_teams_source(n).replace(" teams distribute", ""),
            lambda: (np.int32(n), np.float32(2.5), x, y.copy()),
        ),
        (
            "chain",
            teams_chain_source(3, n),
            teams_chain_source(3, n).replace(" teams distribute", ""),
            lambda: tuple([np.int32(n)] + [b.copy() for b in cbufs]),
        ),
        (
            "redchain",
            chain_with_reduction_source(2, n, teams=True),
            chain_with_reduction_source(2, n),
            lambda: tuple([np.int32(n)] + [b.copy() for b in rbufs]
                          + [np.float32(0.5)]),
        ),
    ]

    all_parity = True
    total_mesh_launches = 0
    total_collectives = 0
    for wname, src_teams, src_single, args_fn in workloads:
        single = compile_fortran(src_single)
        mesh = compile_fortran(src_teams)
        loop = compile_fortran(src_teams, teams_mesh=False)

        env_m = DeviceDataEnvironment()
        out_m = mesh.run(wname, args=args_fn(), env=env_m)
        env_l = DeviceDataEnvironment()
        out_l = loop.run(wname, args=args_fn(), env=env_l)
        out_s = single.run(wname, args=args_fn())

        if wname == "redchain":
            # two parity contracts: the non-mesh loop rung clamps the
            # reduction to the plain schedule (bitwise == single), and
            # the mesh's chunked cross-device combine is bitwise
            # *league-invariant* (== the chunked league-1 reference);
            # plain vs chunked differ in combine order, so those two
            # are only compared numerically
            league1 = compile_fortran(
                chain_with_reduction_source(2, n, num_teams=1, teams=True)
            )
            out_1 = league1.run(wname, args=args_fn())
            parity = (
                _parity(out_l["acc"], out_s["acc"])
                and _parity(out_m["acc"], out_1["acc"])
            )
            ref_close = bool(np.allclose(
                np.asarray(out_m["acc"]), np.asarray(out_s["acc"]),
                rtol=1e-4,
            ))
        else:
            keys = [k for k in out_s if np.ndim(out_s[k]) == 1]
            parity = all(
                _parity(out_m[k], out_s[k]) and _parity(out_l[k], out_s[k])
                for k in keys
            )
            ref_close = True
        all_parity = all_parity and parity and ref_close

        t_single, ts_single = _bench(single, wname, args_fn, iters)
        t_mesh, ts_mesh = _bench(mesh, wname, args_fn, iters)
        t_loop, ts_loop = _bench(loop, wname, args_fn, iters)

        fn = next(
            f for k, f in mesh.executor()._compiled.items()
            if k.startswith(wname)
        )
        total_mesh_launches += env_m.stats.mesh_launches
        total_collectives += env_m.stats.collective_reductions
        result["workloads"][wname] = {
            "single_us": t_single * 1e6,
            "mesh_us": t_mesh * 1e6,
            "loop_us": t_loop * 1e6,
            "single_latency": percentiles(ts_single),
            "mesh_latency": percentiles(ts_mesh),
            "loop_latency": percentiles(ts_loop),
            "speedup_vs_single": t_single / max(t_mesh, 1e-12),
            "speedup_vs_loop": t_loop / max(t_mesh, 1e-12),
            "num_teams": int(getattr(fn, "num_teams", 1)),
            "n_dispatches_mesh": int(getattr(fn, "n_pallas_calls", 1)),
            "mesh_launches": env_m.stats.mesh_launches,
            "collective_reductions": env_m.stats.collective_reductions,
            "sharded_allocs": env_m.stats.sharded_allocs,
            "bit_identical": parity,
        }
        emit(
            f"teams/{wname}_single", t_single * 1e6, f"n={n} devices=1"
        )
        emit(
            f"teams/{wname}_mesh", t_mesh * 1e6,
            f"devices={n_dev} dispatches=1 "
            f"speedup_vs_single={t_single / max(t_mesh, 1e-12):.2f}x "
            f"speedup_vs_loop={t_loop / max(t_mesh, 1e-12):.2f}x",
        )
        emit(
            f"teams/{wname}_loop", t_loop * 1e6,
            f"devices={n_dev} dispatches_per_launch="
            f"{result['workloads'][wname]['num_teams']}",
        )

    # -- device(0) pinning stays on the per-team loop ---------------------
    pinned = compile_fortran(saxpy_teams_source(n, device=0))
    env_p = DeviceDataEnvironment()
    out_p = pinned.run(
        "saxpy", args=(np.int32(n), np.float32(2.5), x, y.copy()), env=env_p
    )
    single_sx = compile_fortran(
        saxpy_teams_source(n).replace(" teams distribute", "")
    )
    out_sx = single_sx.run(
        "saxpy", args=(np.int32(n), np.float32(2.5), x, y.copy())
    )
    pin_parity = _parity(out_p["y"], out_sx["y"])
    emit(
        "teams/device_pinned", 0.0,
        f"device_pinned_launches={env_p.stats.device_pinned_launches} "
        f"parity={pin_parity}",
    )

    # -- trace attribution: per-device kernel windows of one mesh run -----
    traced = compile_fortran(saxpy_teams_source(n), trace=True)
    traced.run("saxpy", args=(np.int32(n), np.float32(2.5), x, y.copy()))
    windows = _team_windows(traced.tracer)
    # the analytics overlap matrix is the general form of the old
    # inline pair count: per-track-pair intersecting-window counts and
    # simultaneously-busy seconds over the mesh team spans
    matrix = overlap_matrix(
        normalize_spans(traced.tracer),
        cats=("team",), require_args={"mesh": True},
    )
    overlap = matrix["overlapping_pairs"]
    traced.write_trace(_TRACE_JSON)
    emit(
        "teams/dispatch_overlap", 0.0,
        f"team_windows={len(windows)} overlapping_pairs={overlap} "
        f"overlap_s={matrix['overlap_s']:.6f}",
    )

    result.update(
        mesh_launches=total_mesh_launches,
        collective_reductions=total_collectives,
        device_pinned_launches=env_p.stats.device_pinned_launches,
        bit_identical=all_parity,
        pinned_bit_identical=pin_parity,
        team_windows=windows,
        overlap_matrix=matrix,
        overlapping_window_pairs=overlap,
        trace_artifact=_TRACE_JSON,
    )
    write_json_atomic("BENCH_teams.json", result)
    if smoke:
        assert n_dev > 1, (
            f"teams smoke needs >1 device (run via `benchmarks.run --smoke "
            f"teams` or set XLA_FLAGS); got {n_dev}"
        )
        assert all_parity, "teams schedules diverged from reference"
        assert pin_parity, "device(0) schedule diverged from single-device"
        assert total_mesh_launches > 0, result
        assert total_collectives > 0, result
        assert overlap > 0, (
            "mesh launch produced no overlapping per-device kernel "
            "windows", windows,
        )
        assert env_p.stats.device_pinned_launches > 0, result
        print(
            f"# smoke ok: {total_mesh_launches} mesh launches over {n_dev} "
            f"devices, {overlap} overlapping team windows, "
            f"{total_collectives} collective reductions -> BENCH_teams.json"
        )
    return result


def main() -> None:
    import sys

    # --no-header: benchmarks.run already printed the CSV header before
    # re-executing this module in the forced-multi-device subprocess
    if "--no-header" not in sys.argv:
        print("name,us_per_call,derived")
    res = run(smoke="--smoke" in sys.argv)
    if "--smoke" not in sys.argv:
        sx = res["workloads"]["saxpy"]
        print(
            f"# mesh teams over {res['devices']} devices: "
            f"{sx['speedup_vs_single']:.2f}x vs single, "
            f"{sx['speedup_vs_loop']:.2f}x vs per-team loop "
            f"(overlapping windows={res['overlapping_window_pairs']}, "
            f"bit_identical={res['bit_identical']})"
        )


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    main()
