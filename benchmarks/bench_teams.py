"""Multi-device teams-distribute benchmark / CI smoke lane.

The saxpy workload compiled two ways:

  single — ``target parallel do``: one kernel, one device;
  teams  — ``target teams distribute parallel do``: the grid's row
           space split into one contiguous slice per device, one
           ``pallas_call`` dispatched per team (JAX's async dispatch
           overlaps them), mapped buffers sharded over the device axis
           by the DeviceDataEnvironment policy.

Results must be bit-identical (every element computed by exactly one
team with single-device arithmetic).  The smoke lane gates on the
counters (``teams_kernels > 0``, ``sharded_allocs > 0``,
``device_pinned_launches > 0``) and parity, and writes
``BENCH_teams.json``.

Run under a forced multi-device host platform:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.bench_teams [--smoke]

or let the harness set the flag for you:

    PYTHONPATH=src python -m benchmarks.run --smoke teams
"""

from __future__ import annotations

import json
import time
from typing import Dict

import numpy as np

try:
    from .common import emit, percentiles
except ImportError:  # standalone: python benchmarks/bench_teams.py
    from common import emit, percentiles

import jax

from repro.core import compile_fortran
from repro.core.runtime import DeviceDataEnvironment
from repro.core.workloads import saxpy_teams_source


def _bench(prog, args_fn, iters: int):
    times = []
    for _ in range(iters + 1):  # first pass warms the jit caches
        a = args_fn()
        t0 = time.perf_counter()
        prog.run("saxpy", args=a)
        times.append(time.perf_counter() - t0)
    warmed = times[1:]
    return float(np.median(warmed)), warmed


def run(smoke: bool = False) -> Dict[str, float]:
    n_dev = len(jax.devices())
    n = 4096 if smoke else 65536
    iters = 3 if smoke else 5

    src_teams = saxpy_teams_source(n)
    src_single = src_teams.replace(" teams distribute", "")
    src_pinned = saxpy_teams_source(n, device=0)

    teams = compile_fortran(src_teams)
    single = compile_fortran(src_single)
    pinned = compile_fortran(src_pinned)

    rng = np.random.default_rng(0)
    x = rng.normal(size=n).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)

    def args_fn():
        return (np.int32(n), np.float32(2.5), x, y.copy())

    # correctness parity: teams/pinned schedules are bit-identical to
    # the single-device schedule
    env = DeviceDataEnvironment()
    out_t = teams.run("saxpy", args=args_fn(), env=env)
    out_s = single.run("saxpy", args=args_fn())
    parity = bool(
        np.array_equal(np.asarray(out_t["y"]), np.asarray(out_s["y"]))
    )
    env_p = DeviceDataEnvironment()
    out_p = pinned.run("saxpy", args=args_fn(), env=env_p)
    pin_parity = bool(
        np.array_equal(np.asarray(out_p["y"]), np.asarray(out_s["y"]))
    )

    teams_kernels = env.stats.teams_kernels
    sharded_allocs = env.stats.sharded_allocs
    pinned_launches = env_p.stats.device_pinned_launches
    (kname,) = (
        k for k in teams.executor()._compiled if k.startswith("saxpy")
    )
    num_teams = getattr(teams.executor()._compiled[kname], "num_teams", 1)

    t_single, ts_single = _bench(single, args_fn, iters)
    t_teams, ts_teams = _bench(teams, args_fn, iters)
    speedup = t_single / max(t_teams, 1e-12)

    emit("teams/single_device", t_single * 1e6, f"n={n} devices=1")
    emit(
        "teams/distributed",
        t_teams * 1e6,
        f"devices={n_dev} num_teams={num_teams} "
        f"speedup_vs_single={speedup:.2f}x "
        f"sharded_allocs={sharded_allocs}",
    )
    emit(
        "teams/device_pinned", 0.0,
        f"device_pinned_launches={pinned_launches} parity={pin_parity}",
    )

    result = {
        "n": n,
        "devices": n_dev,
        "num_teams": num_teams,
        "single_us": t_single * 1e6,
        "teams_us": t_teams * 1e6,
        "single_latency": percentiles(ts_single),
        "teams_latency": percentiles(ts_teams),
        "speedup_vs_single": speedup,
        "teams_kernels": teams_kernels,
        "sharded_allocs": sharded_allocs,
        "device_pinned_launches": pinned_launches,
        "bit_identical": parity,
        "pinned_bit_identical": pin_parity,
    }
    if smoke:
        with open("BENCH_teams.json", "w") as f:
            json.dump(result, f, indent=2)
        assert n_dev > 1, (
            f"teams smoke needs >1 device (run via `benchmarks.run --smoke "
            f"teams` or set XLA_FLAGS); got {n_dev}"
        )
        assert parity, "teams schedule diverged from single-device"
        assert pin_parity, "device(0) schedule diverged from single-device"
        assert teams_kernels > 0, result
        assert sharded_allocs > 0, result
        assert pinned_launches > 0, result
        print(
            f"# smoke ok: teams over {n_dev} devices bit-identical, "
            f"{sharded_allocs} sharded allocs -> BENCH_teams.json"
        )
    return result


def main() -> None:
    import sys

    # --no-header: benchmarks.run already printed the CSV header before
    # re-executing this module in the forced-multi-device subprocess
    if "--no-header" not in sys.argv:
        print("name,us_per_call,derived")
    res = run(smoke="--smoke" in sys.argv)
    if "--smoke" not in sys.argv:
        print(
            f"# teams distribute over {res['devices']} devices: "
            f"{res['speedup_vs_single']:.2f}x vs single "
            f"(bit_identical={res['bit_identical']})"
        )


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    main()
