"""Perf-trajectory emitter: merge every ``BENCH_*.json`` into one file.

    PYTHONPATH=src python -m benchmarks.history [--root DIR] [--out PATH]

Each smoke lane writes its own ``BENCH_<lane>.json`` artifact; this
module folds the headline numbers of all of them into a single
``BENCH_trajectory.json`` so the regression sentry — and any future PR
citing a perf delta — reads the whole trend from one place instead of
globbing per-lane files.

The merge keeps the *scalars* of each lane (top-level numbers, booleans
and short strings, plus one nested level for dict-of-scalar groups like
``counters`` or per-workload timing tables) and drops the bulky
evidence payloads (span interval lists, per-trial logs): the trajectory
is the trend line, the per-lane artifacts remain the proof.
"""

from __future__ import annotations

import argparse
import glob
import os
from typing import Any, Dict

try:
    from .common import write_json_atomic
except ImportError:  # standalone: python benchmarks/history.py
    from common import write_json_atomic

import json

SCHEMA_VERSION = 1

TRAJECTORY_JSON = "BENCH_trajectory.json"

#: artifacts that are aggregates themselves, never folded back in
_EXCLUDE = {TRAJECTORY_JSON, "BENCH_sentry_baselines.json"}

_MAX_STR = 120


def _scalar(v: Any) -> bool:
    return (
        isinstance(v, bool)
        or isinstance(v, (int, float))
        or (isinstance(v, str) and len(v) <= _MAX_STR)
        or v is None
    )


def _summarize(doc: Any) -> Dict[str, Any]:
    """Top-level scalars of a lane artifact, plus one nested level for
    dict-of-scalar groups (``counters``, per-workload tables, ...)."""
    if not isinstance(doc, dict):
        return {}
    out: Dict[str, Any] = {}
    for key, val in doc.items():
        if _scalar(val):
            out[key] = val
        elif isinstance(val, dict):
            nested: Dict[str, Any] = {}
            for k2, v2 in val.items():
                if _scalar(v2):
                    nested[k2] = v2
                elif isinstance(v2, dict):
                    flat = {k3: v3 for k3, v3 in v2.items() if _scalar(v3)}
                    if flat:
                        nested[k2] = flat
            if nested:
                out[key] = nested
    return out


def collect(root: str = ".") -> Dict[str, Any]:
    """Scan ``root`` for lane artifacts and fold them into the
    trajectory document (unreadable/corrupt artifacts are skipped and
    listed, never fatal — a crashed lane must not hide the others)."""
    lanes: Dict[str, Any] = {}
    skipped = []
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        base = os.path.basename(path)
        if base in _EXCLUDE:
            continue
        lane = base[len("BENCH_"):-len(".json")]
        try:
            with open(path, "r") as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError):
            skipped.append(base)
            continue
        lanes[lane] = _summarize(doc)
    out: Dict[str, Any] = {
        "schema": SCHEMA_VERSION,
        "lanes": lanes,
        "n_lanes": len(lanes),
    }
    if skipped:
        out["skipped"] = skipped
    return out


def emit_trajectory(root: str = ".", out: str = TRAJECTORY_JSON) -> str:
    doc = collect(root)
    path = out if os.path.dirname(out) else os.path.join(root, out)
    return write_json_atomic(path, doc)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.history",
        description="merge BENCH_*.json artifacts into one trajectory",
    )
    ap.add_argument("--root", default=".",
                    help="directory holding the BENCH_*.json artifacts")
    ap.add_argument("--out", default=TRAJECTORY_JSON,
                    help=f"output path (default {TRAJECTORY_JSON})")
    args = ap.parse_args(argv)
    path = emit_trajectory(args.root, args.out)
    doc = json.load(open(path))
    print(
        f"trajectory: {doc['n_lanes']} lane(s) "
        f"({', '.join(sorted(doc['lanes']))}) -> {path}"
    )
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
