"""Static-analyzer benchmark / CI smoke lane.

Three gates keep the analyzer honest:

  seeded      — fixture programs each seeded with exactly one defect
                (nowait RAW race, lost-update map(to:) write, VMEM
                blow-up) must produce exactly their expected diagnostic
                code, and the depend-fixed race variant must analyze
                clean.  A detector that rots silently fails the lane.
  clean       — the full shipped corpus (workloads.py generators plus
                every Fortran payload in examples/) analyzes strict-mode
                clean: analyzer false positives can never land quietly.
  overhead    — ``compile_fortran(analyze="warn")`` vs ``analyze="off"``
                on the saxpy-chain workload must cost < 5% extra compile
                wall time (median of repeated compiles).

Artifacts: ``BENCH_analyze.json`` plus CSV ``emit`` rows.

    PYTHONPATH=src python -m benchmarks.bench_analyze [--smoke]
    PYTHONPATH=src python -m benchmarks.run --smoke analyze
"""

from __future__ import annotations

import pathlib
import re
import time
from typing import Any, Dict, List

import numpy as np

try:
    from .common import emit, percentiles, write_json_atomic
except ImportError:  # standalone: python benchmarks/bench_analyze.py
    from common import emit, percentiles, write_json_atomic

from repro.core import analyze_fortran, compile_fortran
from repro.core import workloads as W

_EXAMPLES = pathlib.Path(__file__).resolve().parent.parent / "examples"

#: overhead gate: analyze="warn" adds < 5% to compile wall time
_OVERHEAD_GATE_PCT = 5.0

_RACY = """\
program racy
  real :: x(1024), y(1024), z(1024)
  integer :: i
  !$omp target map(to: x) map(from: y) nowait
  do i = 1, 1024
    y(i) = x(i) * 2.0
  end do
  !$omp end target
  !$omp target map(to: y) map(from: z) nowait
  do i = 1, 1024
    z(i) = y(i) + 1.0
  end do
  !$omp end target
  !$omp taskwait
end program
"""

_RACY_FIXED = _RACY.replace(
    "map(to: x) map(from: y) nowait",
    "map(to: x) map(from: y) nowait depend(out: y)",
).replace(
    "map(to: y) map(from: z) nowait",
    "map(to: y) map(from: z) nowait depend(in: y)",
)

_LOST_UPDATE = """\
real :: x(64), y(64)
integer :: i
!$omp target map(to: x) map(from: y)
do i = 1, 64
  x(i) = x(i) + 1.0
  y(i) = x(i)
end do
!$omp end target
"""

_VMEM = """\
real :: a(1024), b(1024), c(1024)
integer :: i
!$omp target map(to: a, b) map(from: c)
do i = 1, 1024
  c(i) = a(i) + b(i)
end do
!$omp end target
"""

#: (fixture name, source, analyze kwargs, expected diagnostic codes)
_SEEDED = (
    ("race", _RACY, {}, ["race"]),
    ("race_fixed", _RACY_FIXED, {}, []),
    ("lost_update", _LOST_UPDATE, {}, ["lost-update"]),
    ("vmem", _VMEM, {"vmem_budget": 1024}, ["vmem-exceeded"]),
)


def _corpus() -> Dict[str, str]:
    """Everything we ship: workloads.py generators + examples/ payloads."""
    corpus = {
        "saxpy_teams": W.saxpy_teams_source(1024),
        "saxpy_teams_league": W.saxpy_teams_source(1024, num_teams=2),
        "saxpy_teams_device": W.saxpy_teams_source(1024, device=0),
        "teams_chain": W.teams_chain_source(3, 1024),
        "chain": W.chain_source(3, 1024),
        "chain_reduction": W.chain_with_reduction_source(3, 1024),
        "chain_reduction_teams": W.chain_with_reduction_source(
            3, 1024, teams=True
        ),
        "sgesl_chain": W.sgesl_chain_source(64),
    }
    for p in sorted(_EXAMPLES.glob("*.py")):
        text = p.read_text()
        for i, m in enumerate(re.finditer(r'"""(.*?)"""', text, re.S)):
            body = m.group(1)
            # Fortran payloads only: a line *starting* with the sentinel
            # (prose docstrings mention !$omp mid-line)
            if any(
                l.lstrip().startswith("!$omp") for l in body.splitlines()
            ):
                corpus[f"{p.name}:{i}"] = body.replace("{N}", "1024")
    return corpus


def _time_analysis(source: str, iters: int) -> float:
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        analyze_fortran(source, device_count=4)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _overhead_phase(iters: int) -> Dict[str, Any]:
    """compile_fortran(analyze="warn") vs analyze="off" on saxpy-chain."""
    src = W.chain_source(3, 4096)
    on, off = [], []
    for _ in range(iters + 1):  # first pass warms import/jit caches
        t0 = time.perf_counter()
        compile_fortran(src, analyze="off")
        off.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        compile_fortran(src, analyze="warn")
        on.append(time.perf_counter() - t0)
    off_s = float(np.median(off[1:]))
    on_s = float(np.median(on[1:]))
    return {
        "compile_off_us": off_s * 1e6,
        "compile_warn_us": on_s * 1e6,
        "compile_off_latency": percentiles(off[1:]),
        "compile_warn_latency": percentiles(on[1:]),
        "overhead_pct": (on_s / max(off_s, 1e-12) - 1.0) * 100.0,
    }


def run(smoke: bool = False) -> Dict[str, Any]:
    iters = 3 if smoke else 10

    # -- seeded fixtures: each defect produces exactly its code ----------
    seeded: List[Dict[str, Any]] = []
    for name, src, kwargs, expected in _SEEDED:
        t0 = time.perf_counter()
        diags = analyze_fortran(src, device_count=4, **kwargs)
        dt = time.perf_counter() - t0
        got = [d.code for d in diags]
        seeded.append({
            "fixture": name,
            "expected": expected,
            "got": got,
            "ok": got == expected,
            "analyze_us": dt * 1e6,
        })
        emit(
            f"analyze/seeded_{name}", dt * 1e6,
            f"expected={expected} got={got}",
        )

    # -- clean corpus: strict mode over everything we ship ---------------
    corpus = _corpus()
    dirty: Dict[str, List[str]] = {}
    t0 = time.perf_counter()
    for name, src in sorted(corpus.items()):
        diags = analyze_fortran(src, device_count=4)
        if diags:
            dirty[name] = [d.code for d in diags]
    corpus_s = time.perf_counter() - t0
    emit(
        "analyze/clean_corpus", corpus_s * 1e6,
        f"programs={len(corpus)} dirty={len(dirty)}",
    )

    # -- analyzer latency + compile overhead -----------------------------
    t_analyze = _time_analysis(_RACY, iters)
    overhead = _overhead_phase(iters)
    emit(
        "analyze/latency", t_analyze * 1e6,
        f"fixture=race iters={iters}",
    )
    emit(
        "analyze/compile_overhead", overhead["compile_warn_us"],
        f"off={overhead['compile_off_us']:.0f}us "
        f"overhead={overhead['overhead_pct']:.2f}%",
    )

    result = {
        "seeded": seeded,
        "corpus_programs": len(corpus),
        "corpus_dirty": dirty,
        "analyze_us": t_analyze * 1e6,
        "overhead": overhead,
        "overhead_gate_pct": _OVERHEAD_GATE_PCT,
    }
    write_json_atomic("BENCH_analyze.json", result)

    if smoke:
        bad = [s for s in seeded if not s["ok"]]
        assert not bad, ("seeded fixture diagnostics drifted", bad)
        assert not dirty, (
            "analyzer flagged shipped programs (false positives)", dirty
        )
        assert overhead["overhead_pct"] < _OVERHEAD_GATE_PCT, (
            f"analyze='warn' costs {overhead['overhead_pct']:.2f}% of "
            f"compile time (gate: < {_OVERHEAD_GATE_PCT}%)", overhead
        )
        print(
            f"# smoke ok: {len(seeded)} seeded fixtures exact, "
            f"{len(corpus)} corpus programs clean, analyze="
            f"{t_analyze * 1e6:.0f}us, compile overhead "
            f"{overhead['overhead_pct']:.2f}% -> BENCH_analyze.json"
        )
    return result


def main() -> None:
    import sys

    if "--no-header" not in sys.argv:
        print("name,us_per_call,derived")
    res = run(smoke="--smoke" in sys.argv)
    if "--smoke" not in sys.argv:
        print(
            f"# analyze: corpus={res['corpus_programs']} "
            f"overhead={res['overhead']['overhead_pct']:.2f}%"
        )


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    main()
