"""Roofline table from the dry-run artifacts (EXPERIMENTS.md source).

Reads benchmarks/results/dryrun/*.json and emits per (arch x shape x
mesh): the three terms, bottleneck, MODEL_FLOPS/HLO_FLOPs ratio and the
per-device memory picture.
"""

from __future__ import annotations

import glob
import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_all():
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs) -> str:
    rows = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "bottleneck | useful_flops | roofline_frac | peak_mem/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"skipped | — | — | {r['reason']} |"
            )
            continue
        if r.get("status") != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"ERROR | — | — | {r.get('error','?')[:60]} |"
            )
            continue
        pm = r.get("peak_memory_bytes") or 0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.3f} "
            f"| {pm/1e9:.2f} GB |"
        )
    return "\n".join(rows)


def run() -> None:
    recs = load_all()
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    errors = [r for r in recs if r.get("status") == "error"]
    emit("roofline_cells_ok", 0.0, f"count={len(ok)}")
    emit("roofline_cells_skipped", 0.0, f"count={len(skipped)}")
    emit("roofline_cells_error", 0.0, f"count={len(errors)}")
    for r in ok:
        emit(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.3f}",
        )
    table = markdown_table(recs)
    out = os.path.join(RESULTS, "..", "roofline_table.md")
    with open(out, "w") as f:
        f.write(table + "\n")
    print(f"# roofline table written to {os.path.abspath(out)}")


if __name__ == "__main__":
    run()
