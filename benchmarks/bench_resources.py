"""Paper Tables 3-6 analogue: resource utilisation + energy proxy.

The U280 LUT/BRAM/DSP columns have no TPU meaning; the compiled-artifact
resources that do: VMEM working set claimed by the BlockSpecs, HLO FLOPs
and bytes moved. Tables 5-6 (power) are replaced by the bytes-per-FLOP
energy proxy (no power rail in this container) — documented in
EXPERIMENTS.md.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import compile_fortran
from repro.core.backend.pallas_codegen import analyze
from repro.kernels.saxpy.kernel import LANE
from .common import emit

SAXPY_SRC = """
subroutine saxpy(n, a, x, y)
  integer :: n
  real :: a
  real :: x({N}), y({N})
  integer :: i
  !$omp target parallel do simd simdlen(10)
  do i = 1, n
    y(i) = y(i) + a * x(i)
  end do
  !$omp end target parallel do simd
end subroutine
"""


def run() -> None:
    n = 1_000_000
    prog = compile_fortran(SAXPY_SRC.format(N=n))
    func = next(iter(prog.device_module.funcs().values()))
    plan = analyze(func)

    # generated kernel resources
    vmem_gen = plan.vmem_bytes()
    emit("saxpy_generated_vmem_bytes", 0.0, f"bytes={vmem_gen}")
    emit("saxpy_generated_block", 0.0,
         f"block={plan.block_rows}x{LANE};grid={plan.n // plan.block + 1}")

    # hand-written kernel resources (same BlockSpec tiling by design)
    vmem_hand = (3 * plan.block * 4)  # x, y, out blocks f32
    emit("saxpy_handwritten_vmem_bytes", 0.0, f"bytes={vmem_hand}")

    # energy proxy: bytes moved per FLOP (saxpy: 2 flops, 12 bytes/elem)
    flops = 2 * n
    bytes_moved = 3 * 4 * n
    emit("saxpy_energy_proxy", 0.0,
         f"bytes_per_flop={bytes_moved/flops:.2f};"
         f"note=power-tables-5-6-replaced-by-proxy")


if __name__ == "__main__":
    run()
