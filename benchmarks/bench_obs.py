"""Observability benchmark / CI smoke lane.

Two phases:

  traced   — the fused teams-chain workload runs with tracing enabled
             over forced multi-device hosts: the exported
             Chrome-trace/Perfetto JSON is validated against the schema
             the viewers expect (metadata-named pid/tid rows, complete
             "X" events, sorted timestamps), and gated on one track per
             stream, one per device (per-team spans), and DMA spans
             carrying byte counts.  Request latencies land in a
             :class:`~repro.core.obs.Histogram` whose Prometheus render
             must parse strictly and carry p50/p95/p99 quantiles plus
             every live TransferStats counter.  The trace file
             (``repro_trace_obs.json``) is uploaded as a CI artifact.
  overhead — the guard that keeps tracing default-off honest: on the
             saxpy-chain hot path (launch-plan replay), the *disabled*
             tracer's cost is modelled as spans-per-replay (counted from
             a traced twin run) times the measured cost of one no-op
             tracer call, and must stay under 1% of the median replay
             time.  The model is deliberately an upper bound — the real
             instrumented sites guard with one ``tracer.enabled``
             attribute read, which is cheaper than the null ``span()``
             call measured here.

Writes ``BENCH_obs.json``; ``--smoke`` asserts the gates.

    PYTHONPATH=src python -m benchmarks.run --smoke obs
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, Set

import numpy as np

try:
    from .common import emit, percentiles, write_json_atomic
except ImportError:  # standalone: python benchmarks/bench_obs.py
    from common import emit, percentiles, write_json_atomic

import jax

from repro.core import compile_fortran
from repro.core.obs import MetricsRegistry, Tracer, parse_prometheus
from repro.core.runtime import DeviceDataEnvironment
from repro.core.workloads import chain_source, teams_chain_source

_TRACE_JSON = "repro_trace_obs.json"


def validate_chrome_trace(doc: Dict[str, Any]) -> Dict[str, Set[str]]:
    """Schema gate for exported traces: only "M"/"X" events, X events
    complete (non-negative ts+dur) and sorted by timestamp, and every
    (pid, tid) an X event uses named by process/thread metadata.
    Returns the track names per lane so callers can gate coverage."""
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    xs = [e for e in events if e["ph"] == "X"]
    assert meta and xs, "trace must carry metadata and complete events"
    assert all(e["ph"] in ("M", "X") for e in events), "unexpected phase"
    ts = [e["ts"] for e in xs]
    assert ts == sorted(ts), "X events not sorted by timestamp"
    assert all(e["ts"] >= 0.0 and e["dur"] >= 0.0 for e in xs), (
        "incomplete/negative X event"
    )
    lane_of = {
        e["pid"]: e["args"]["name"]
        for e in meta if e["name"] == "process_name"
    }
    track_of = {
        (e["pid"], e["tid"]): e["args"]["name"]
        for e in meta if e["name"] == "thread_name"
    }
    for e in xs:
        assert e["pid"] in lane_of, f"unnamed pid {e['pid']}"
        assert (e["pid"], e["tid"]) in track_of, (
            f"unnamed tid {e['tid']} in pid {e['pid']}"
        )
    tracks: Dict[str, Set[str]] = {}
    for (pid, _tid), name in track_of.items():
        tracks.setdefault(lane_of[pid], set()).add(name)
    return tracks


def _traced_phase(n: int, stages: int, iters: int) -> Dict[str, Any]:
    tracer = Tracer()
    prog = compile_fortran(teams_chain_source(stages, n), trace=tracer)
    env = DeviceDataEnvironment()

    rng = np.random.default_rng(0)
    bufs = [rng.normal(size=n).astype(np.float32) for _ in range(stages + 1)]

    metrics = MetricsRegistry()
    metrics.bind_stats(env.stats)
    latency = metrics.histogram(
        "repro_request_latency_seconds", help="traced request latency"
    )
    latencies = []
    for _ in range(iters + 1):  # first pass warms the jit caches
        args = tuple([np.int32(n)] + [b.copy() for b in bufs])
        with tracer.timed(
            "request", cat="request", lane="serve", track="requests", n=n
        ) as sp:
            prog.run("chain", args=args, env=env)
        latency.observe(sp.dur)
        latencies.append(sp.dur)

    # Prometheus surface: must parse strictly, carry the latency
    # quantiles, and expose every TransferStats counter live
    samples = parse_prometheus(metrics.render())
    quantile_keys = [
        f'repro_request_latency_seconds{{quantile="{q}"}}'
        for q in ("0.5", "0.95", "0.99")
    ]
    stats_keys = [
        f"repro_offload_{f}_total" for f in env.stats.snapshot()
    ]

    # timeline surface: kernel windows per stream, team slices per
    # device, DMAs with byte counts — then the schema gate on the export
    kernel_tracks = {s.track for s in tracer.spans(cat="kernel")}
    team_tracks = {s.track for s in tracer.spans(cat="team")}
    dma_spans = tracer.spans(cat="dma")
    doc = tracer.chrome_trace()
    lane_tracks = validate_chrome_trace(doc)
    tracer.write_chrome_trace(_TRACE_JSON)

    return {
        "n": n,
        "stages": stages,
        "devices": len(jax.devices()),
        "requests": iters + 1,
        "latency": percentiles(latencies[1:]),
        "spans": len(tracer),
        "stream_tracks": sorted(kernel_tracks),
        "device_tracks": sorted(team_tracks),
        "dma_spans": len(dma_spans),
        "dma_bytes_tagged": all(s.args.get("bytes", 0) > 0
                                for s in dma_spans),
        "trace_lanes": {k: sorted(v) for k, v in lane_tracks.items()},
        "metrics_parse_ok": True,
        "latency_quantiles_ok": all(k in samples for k in quantile_keys),
        "stats_counters_ok": all(k in samples for k in stats_keys),
        "trace_file": _TRACE_JSON,
    }


def _overhead_phase(n: int, stages: int, iters: int) -> Dict[str, Any]:
    src = chain_source(stages, n)

    # hot path: launch-plan replay with the default (disabled) tracer
    prog = compile_fortran(src)
    rng = np.random.default_rng(0)
    bufs = [rng.normal(size=n).astype(np.float32) for _ in range(stages + 1)]

    def args_fn():
        return tuple([np.int32(n)] + [b.copy() for b in bufs])

    times = []
    for _ in range(iters + 1):  # first pass warms jit + the launch plan
        a = args_fn()
        t0 = time.perf_counter()
        prog.run("chain", args=a)
        times.append(time.perf_counter() - t0)
    replay_s = float(np.median(times[1:]))

    # spans per replay, counted from a traced twin of the same workload
    tr = Tracer()
    twin = compile_fortran(src, trace=tr)
    twin.run("chain", args=args_fn())  # warm (includes compile spans)
    tr.clear()
    twin.run("chain", args=args_fn())
    spans_per_replay = len(tr)

    # measured cost of one no-op call on a disabled tracer (upper bound
    # on what an instrumented site pays when tracing is off)
    null = Tracer(enabled=False)
    calls = 100_000
    t0 = time.perf_counter()
    for _ in range(calls):
        with null.span("x"):
            pass
    per_call_s = (time.perf_counter() - t0) / calls

    overhead = spans_per_replay * per_call_s / max(replay_s, 1e-12)
    return {
        "replay_us": replay_s * 1e6,
        "replay_latency": percentiles(times[1:]),
        "spans_per_replay": spans_per_replay,
        "null_call_ns": per_call_s * 1e9,
        "disabled_overhead_pct": overhead * 100.0,
    }


def run(smoke: bool = False) -> Dict[str, Any]:
    n_dev = len(jax.devices())
    n = 4096 if smoke else 65536
    iters = 4 if smoke else 8

    traced = _traced_phase(n, stages=3, iters=iters)
    overhead = _overhead_phase(n, stages=4, iters=iters)

    lat = traced["latency"]
    emit(
        "obs/traced_request",
        lat["p50_us"],
        f"n={n} devices={n_dev} spans={traced['spans']} "
        f"p95={lat['p95_us']:.1f}us p99={lat['p99_us']:.1f}us",
    )
    emit(
        "obs/disabled_overhead",
        overhead["replay_us"],
        f"spans_per_replay={overhead['spans_per_replay']} "
        f"null_call={overhead['null_call_ns']:.0f}ns "
        f"overhead={overhead['disabled_overhead_pct']:.3f}%",
    )

    result = {"traced": traced, "overhead": overhead}
    if smoke:
        write_json_atomic("BENCH_obs.json", result)
        assert n_dev > 1, (
            f"obs smoke needs >1 device (run via `benchmarks.run --smoke "
            f"obs` or set XLA_FLAGS); got {n_dev}"
        )
        assert traced["metrics_parse_ok"], result
        assert traced["latency_quantiles_ok"], result
        assert traced["stats_counters_ok"], result
        assert traced["stream_tracks"], "no kernel spans on stream tracks"
        assert len(traced["device_tracks"]) == n_dev, (
            f"expected one team track per device, got "
            f"{traced['device_tracks']}"
        )
        assert traced["dma_spans"] > 0 and traced["dma_bytes_tagged"], result
        assert traced["trace_lanes"].get("serve") == ["requests"], result
        assert overhead["disabled_overhead_pct"] < 1.0, (
            f"disabled tracer costs "
            f"{overhead['disabled_overhead_pct']:.3f}% of the "
            f"launch-plan replay hot path (gate: < 1%)"
        )
        print(
            f"# smoke ok: {traced['spans']} spans across "
            f"{len(traced['stream_tracks'])} stream / "
            f"{len(traced['device_tracks'])} device tracks, disabled "
            f"overhead {overhead['disabled_overhead_pct']:.3f}% "
            f"-> BENCH_obs.json + {_TRACE_JSON}"
        )
    return result


def main() -> None:
    import sys

    # --no-header: benchmarks.run already printed the CSV header before
    # re-executing this module in the forced-multi-device subprocess
    if "--no-header" not in sys.argv:
        print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    main()
