"""Paper Table 1: SAXPY — pipeline-generated kernel vs hand-written.

The paper compares its Fortran+OpenMP flow against hand-written HLS on a
U280 across N in {10K, 100K, 1M, 10M}. Here: the offload pipeline's
generated Pallas kernel vs the hand-written Pallas kernel, both in
interpreter mode on CPU (wall clock is *relative* — interpret mode, not
TPU latency), plus the hardware-independent parity check the paper's
Tables 3-4 get at: identical FLOPs/bytes in the compiled HLO.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import compile_fortran
from repro.kernels.saxpy import saxpy as handwritten_saxpy
from .common import emit, time_fn

SAXPY_SRC = """
subroutine saxpy(n, a, x, y)
  integer :: n
  real :: a
  real :: x({N}), y({N})
  integer :: i
  !$omp target parallel do simd simdlen(10)
  do i = 1, n
    y(i) = y(i) + a * x(i)
  end do
  !$omp end target parallel do simd
end subroutine
"""

import os

# The paper sweeps 10K..10M; interpret-mode on CPU makes 10M minutes-slow,
# so the harness default stops at 1M. REPRO_BENCH_FULL=1 restores 10M.
SIZES = [10_000, 100_000, 1_000_000]
if os.environ.get("REPRO_BENCH_FULL"):
    SIZES.append(10_000_000)


def hlo_stats(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    ca = c.cost_analysis() or {}
    return float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0))


def run() -> None:
    rng = np.random.default_rng(0)
    for n in SIZES:
        prog = compile_fortran(SAXPY_SRC.format(N=n))
        kname = next(iter(prog.kernel_backends))
        assert prog.kernel_backends[kname] == "pallas"
        gen_fn = prog.executor().kernels[kname]

        x = rng.normal(size=n).astype(np.float32)
        y = rng.normal(size=n).astype(np.float32)
        a = np.float32(2.0)
        args_gen = (np.float32(2.0).reshape(()), np.int32(n).reshape(()),
                    x, y)
        # generated kernel argument order follows the capture order
        fargs = [np.asarray(v) for v in (a, np.int32(n), x, y)]

        t_gen, s_gen = time_fn(gen_fn, *fargs, iters=3)
        t_hand, s_hand = time_fn(handwritten_saxpy, a, x, y, iters=3)

        # correctness parity
        out_gen = np.asarray(gen_fn(*fargs)[3])
        out_hand = np.asarray(handwritten_saxpy(a, x, y))
        assert np.allclose(out_gen, out_hand, rtol=1e-5), n

        diff = (t_gen - t_hand) / t_hand * 100.0
        emit(f"saxpy_generated_n{n}", t_gen * 1e6,
             f"std={s_gen*1e6:.1f}us")
        emit(f"saxpy_handwritten_n{n}", t_hand * 1e6,
             f"std={s_hand*1e6:.1f}us;diff={diff:+.2f}%")


if __name__ == "__main__":
    run()
