"""Paper Table 2: SGESL — flow-generated vs hand-written kernels.

N in {256, 512, 1024, 2048} like the paper. Two comparisons:
  * kernel-level (the paper's measurement: device time only): the
    pipeline-generated Pallas kernel vs the hand-written one, one solve's
    worth of inner-loop dispatches;
  * end-to-end through the host executor (extra, shows host-interpreter
    overhead of the device-dialect runtime — the paper's equivalent cost
    is its generated C++/OpenCL host code, effectively zero).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import compile_fortran
from repro.kernels.sgesl import sgesl_update
from .common import emit, time_fn

SGESL_SRC = """
subroutine sgesl_loop(n, a, b, ipvt)
  integer :: n
  real :: a({N}), b({N})
  integer :: ipvt({N})
  integer :: k, l, j
  real :: t
  do k = 1, n - 1
    l = ipvt(k)
    t = b(l)
    if (l /= k) then
      b(l) = b(k)
      b(k) = t
    end if
    !$omp target parallel do
    do j=k+1,n
      b(j) = b(j) + t * a(j)
    end do
    !$omp target end parallel do
  end do
end subroutine
"""

SIZES = [256, 512, 1024, 2048]


def run() -> None:
    rng = np.random.default_rng(1)
    for n in SIZES:
        prog = compile_fortran(SGESL_SRC.format(N=n))
        kname = next(iter(prog.kernel_backends))
        assert prog.kernel_backends[kname] == "pallas", kname
        gen_fn = prog.executor().kernels[kname]
        func = prog.device_module.funcs()[kname]
        arg_names = [a.name_hint for a in func.body.args]

        a = (rng.normal(size=n) * 0.01).astype(np.float32)
        b = rng.normal(size=n).astype(np.float32)

        def gen_kernels(iters: int = 16):
            """One solve's worth of generated-kernel dispatches."""
            bj = jnp.asarray(b)
            for k in range(1, iters + 1):
                vals = {"a": a, "b": bj, "n": np.int32(n),
                        "t": np.float32(0.01), "k": np.int32(k)}
                out = gen_fn(*[vals[nm] for nm in arg_names])
                bj = out[arg_names.index("b")]
            return bj

        def hand_kernels(iters: int = 16):
            bj = jnp.asarray(b)
            for k in range(1, iters + 1):
                bj = sgesl_update(np.float32(0.01), a, bj, k, n)
            return bj

        t_gen, s_gen = time_fn(gen_kernels, warmup=1, iters=3)
        t_hand, s_hand = time_fn(hand_kernels, warmup=1, iters=3)
        # correctness parity between the two paths
        np.testing.assert_allclose(np.asarray(gen_kernels(4)),
                                   np.asarray(hand_kernels(4)), rtol=1e-5)
        diff = (t_gen - t_hand) / t_hand * 100.0
        emit(f"sgesl_generated_n{n}", t_gen * 1e6, f"std={s_gen*1e6:.1f}us")
        emit(f"sgesl_handwritten_n{n}", t_hand * 1e6,
             f"std={s_hand*1e6:.1f}us;diff={diff:+.2f}%")

    # end-to-end through the device-dialect host executor (one size)
    n = 256
    prog = compile_fortran(SGESL_SRC.format(N=n))
    a = (rng.normal(size=n) * 0.01).astype(np.float32)
    b = rng.normal(size=n).astype(np.float32)
    ipvt = np.arange(1, n + 1, dtype=np.int32)
    t_e2e, s_e2e = time_fn(
        lambda: prog.run("sgesl_loop", args=(np.int32(n), a, b.copy(), ipvt)),
        warmup=1, iters=3,
    )
    emit(f"sgesl_end_to_end_host_executor_n{n}", t_e2e * 1e6,
         f"std={s_e2e*1e6:.1f}us;includes-host-interpreter-overhead")


if __name__ == "__main__":
    run()
