"""Chaos benchmark / CI smoke lane for the resilient offload runtime.

One teams reduction workload (``redchain``) runs twice over four forced
host devices:

  baseline — fault-free, the plain mesh schedule;
  chaos    — the same program compiled with a scripted fault plan::

      dma_h2d:transient:1;kernel_launch:transient:2;device@1:persistent

    One H2D transfer fails once (retried), the kernel launch fails
    twice (retried), and device 1 then dies outright: the runtime
    quarantines it, re-pins its streams, and re-plans the teams kernel
    over the three survivors (league clamped by the chunked-reduction
    layout, so the degraded mesh stays *bit-identical* to the
    fault-free run).

Recovery claims are attributed with trace evidence, not bare counters:
every retry / quarantine / degrade step is a ``cat="recovery"`` span on
the ``[runtime] resilience`` track, and the smoke gate bounds recovery
latency via the ``obs.analytics`` *phase breakdown* (the ``recovery``
phase row: each retry under the policy deadline, the phase total under
``_RECOVERY_BUDGET_S``) instead of hand-scanning spans.  The recovery
span intervals are embedded in ``BENCH_chaos.json`` and the full
timeline is written to ``repro_trace_chaos.json``.

The lane also keeps resilience default-off honest (the bench_obs
model): the *disabled* engine's cost on the launch-plan replay hot path
is modelled as guarded-sites-per-replay (three ``enabled`` reads per
launch — dispatch, event delay, watchdog — plus one per DMA) times the
measured cost of one null guard, and must stay under 1% of the median
replay.

Run under a forced multi-device host platform:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.bench_chaos [--smoke]

or let the harness set the flag for you:

    PYTHONPATH=src python -m benchmarks.run --smoke chaos
"""

from __future__ import annotations

import time
from typing import Any, Dict, List

import numpy as np

try:
    from .common import emit, percentiles, write_json_atomic
except ImportError:  # standalone: python benchmarks/bench_chaos.py
    from common import emit, percentiles, write_json_atomic

import jax

from repro.core import compile_fortran
from repro.core.obs.analytics import analyze
from repro.core.resilience import NULL_RESILIENCE
from repro.core.runtime import DeviceDataEnvironment
from repro.core.workloads import chain_with_reduction_source

_TRACE_JSON = "repro_trace_chaos.json"

#: the scripted chaos scenario the lane gates on
_FAULT_PLAN = (
    "dma_h2d:transient:1;kernel_launch:transient:2;device@1:persistent"
)

#: upper bound on the whole recovery (sum of recovery span durations);
#: dominated by the one re-compile the survivor re-plan performs
_RECOVERY_BUDGET_S = 30.0


def _bench(prog, args_fn, iters: int):
    times = []
    for _ in range(iters + 1):  # first pass warms the jit caches
        a = args_fn()
        t0 = time.perf_counter()
        prog.run("redchain", args=a)
        times.append(time.perf_counter() - t0)
    return float(np.median(times[1:])), times[1:]


def _recovery_breakdown(report) -> List[Dict[str, Any]]:
    """The chaos run's recovery steps (the analytics ``recovery`` phase
    members) as relative span intervals for the JSON artifact."""
    t0 = report.spans[0].ts if report.spans else 0.0
    return [
        {
            "name": s.name,
            "start_us": (s.ts - t0) * 1e6,
            "dur_us": max(s.dur, 0.0) * 1e6,
            "args": dict(s.args),
        }
        for s in report.phase_members("recovery")
    ]


def _overhead_phase(prog, args_fn, iters: int) -> Dict[str, Any]:
    """Disabled-engine cost on the launch-plan replay path (the
    bench_obs model: guarded sites per replay x one null guard)."""
    ex = prog.executor()
    assert ex.resilience is NULL_RESILIENCE  # the default-off claim

    times = []
    for _ in range(iters + 1):
        a = args_fn()
        stats0 = ex.device_env.stats
        launches0 = sum(ex.scheduler.pool.launch_counts())
        dma0 = stats0.h2d_calls + stats0.d2h_calls + stats0.d2d_calls
        t0 = time.perf_counter()
        prog.run("redchain", args=a)
        times.append(time.perf_counter() - t0)
        launches = sum(ex.scheduler.pool.launch_counts()) - launches0
        dmas = (stats0.h2d_calls + stats0.d2h_calls + stats0.d2d_calls
                - dma0)
    replay_s = float(np.median(times[1:]))
    # per replay: dispatch + event-delay + watchdog guards per launch,
    # one guard per DMA direction call
    guards_per_replay = 3 * launches + dmas

    res = NULL_RESILIENCE
    calls = 100_000
    t0 = time.perf_counter()
    hits = 0
    for _ in range(calls):
        if res.enabled:  # the exact hot-site guard shape
            hits += 1
    per_guard_s = (time.perf_counter() - t0) / calls
    assert hits == 0

    overhead = guards_per_replay * per_guard_s / max(replay_s, 1e-12)
    return {
        "replay_us": replay_s * 1e6,
        "replay_latency": percentiles(times[1:]),
        "guards_per_replay": guards_per_replay,
        "null_guard_ns": per_guard_s * 1e9,
        "disabled_overhead_pct": overhead * 100.0,
    }


def run(smoke: bool = False) -> Dict[str, Any]:
    n_dev = len(jax.devices())
    n = 4096 if smoke else 65536
    stages = 2
    iters = 3 if smoke else 5
    rng = np.random.default_rng(0)
    bufs = [rng.normal(size=n).astype(np.float32) for _ in range(stages + 1)]

    def args_fn():
        return tuple([np.int32(n)] + [b.copy() for b in bufs]
                     + [np.float32(0.5)])

    src = chain_with_reduction_source(stages, n, teams=True)
    out_keys = [f"s{j}" for j in range(stages + 1)] + ["acc"]

    # -- baseline: fault-free mesh run -----------------------------------
    baseline = compile_fortran(src)
    out_b = baseline.run("redchain", args=args_fn())
    t_base, _ = _bench(baseline, args_fn, iters)

    # -- chaos: same program under the scripted fault plan ---------------
    env = DeviceDataEnvironment()
    chaos = compile_fortran(src, fault_plan=_FAULT_PLAN, trace=True)
    out_c = chaos.run("redchain", args=args_fn(), env=env)
    bit_identical = all(
        bool(np.array_equal(np.asarray(out_c[k]), np.asarray(out_b[k])))
        for k in out_keys
    )
    s = env.stats
    ex = chaos.executor()
    res = ex.resilience
    report = analyze(chaos.tracer)
    spans = _recovery_breakdown(report)
    # the phase row's *total* (plain sum of member durations) is the
    # recovery budget the gate bounds; ``self_s`` would under-count
    # retries that overlap the kernel windows they wrap
    recovery_total_s = report.phases["recovery"].total_s
    retry_spans = [sp for sp in spans if sp["name"].startswith("retry:")]
    retries_bounded = all(
        sp["dur_us"] * 1e-6 <= res.retry.deadline_s for sp in retry_spans
    )
    degraded = {
        name: {
            "rung": getattr(fn, "rung", "?"),
            "num_teams": int(getattr(fn, "num_teams", 1)),
            "devices": [
                getattr(d, "id", repr(d))
                for d in (getattr(fn, "team_devices", ()) or ())
            ],
        }
        for name, fn in ex._degraded_fns.items()
    }
    healthz = res.health_snapshot()
    chaos.write_trace(_TRACE_JSON)

    # post-recovery replay: the degraded schedule keeps serving, still
    # bit-identical (the league re-clamp preserves the chunk layout)
    out_r = chaos.run("redchain", args=args_fn())
    replay_identical = all(
        bool(np.array_equal(np.asarray(out_r[k]), np.asarray(out_b[k])))
        for k in out_keys
    )
    t_degraded, _ = _bench(chaos, args_fn, iters)

    overhead = _overhead_phase(baseline, args_fn, iters)

    emit(
        "chaos/baseline_mesh", t_base * 1e6,
        f"n={n} devices={n_dev} stages={stages}",
    )
    emit(
        "chaos/faulted_run", 0.0,
        f"plan={_FAULT_PLAN!r} launch_retries={s.launch_retries} "
        f"dma_retries={s.dma_retries} quarantined={s.quarantined_devices} "
        f"degraded={s.degraded_launches} bit_identical={bit_identical}",
    )
    emit(
        "chaos/recovery", recovery_total_s * 1e6,
        f"spans={len(spans)} retries_bounded={retries_bounded} "
        f"survivor_rungs={sorted(d['rung'] for d in degraded.values())}",
    )
    emit(
        "chaos/degraded_replay", t_degraded * 1e6,
        f"devices={len(healthz['health']['quarantined']) and n_dev - 1 or n_dev} "
        f"vs_baseline={t_degraded / max(t_base, 1e-12):.2f}x "
        f"bit_identical={replay_identical}",
    )
    emit(
        "chaos/disabled_overhead", overhead["replay_us"],
        f"guards_per_replay={overhead['guards_per_replay']} "
        f"null_guard={overhead['null_guard_ns']:.0f}ns "
        f"overhead={overhead['disabled_overhead_pct']:.3f}%",
    )

    result = {
        "workload": "redchain",
        "n": n,
        "stages": stages,
        "devices": n_dev,
        "fault_plan": _FAULT_PLAN,
        "baseline_us": t_base * 1e6,
        "degraded_replay_us": t_degraded * 1e6,
        "bit_identical": bit_identical,
        "replay_bit_identical": replay_identical,
        "counters": {
            k: int(getattr(s, k))
            for k in (
                "launch_retries", "dma_retries", "watchdog_timeouts",
                "quarantined_devices", "degraded_launches", "breaker_open",
            )
        },
        "faults": res.injector.snapshot(),
        "degraded_kernels": degraded,
        "healthz": healthz,
        "recovery_spans": spans,
        "recovery_total_s": recovery_total_s,
        "phase_breakdown": {
            p: st.to_dict() for p, st in report.phases.items()
        },
        "idle_s": report.idle_s,
        "overhead": overhead,
        "trace_artifact": _TRACE_JSON,
    }
    write_json_atomic("BENCH_chaos.json", result)

    if smoke:
        assert n_dev > 1, (
            f"chaos smoke needs >1 device (run via `benchmarks.run --smoke "
            f"chaos` or set XLA_FLAGS); got {n_dev}"
        )
        assert bit_identical, (
            "faulted run diverged from the fault-free baseline", result
        )
        assert replay_identical, (
            "post-recovery replay diverged from the baseline", result
        )
        assert s.launch_retries > 0, result
        assert s.dma_retries > 0, result
        assert s.quarantined_devices == 1, result
        assert s.degraded_launches > 0, result
        assert healthz["status"] == "degraded", result
        assert spans, "no recovery spans recorded"
        assert retries_bounded, (
            "a retry span exceeded the policy deadline", spans
        )
        assert recovery_total_s < _RECOVERY_BUDGET_S, (
            f"recovery took {recovery_total_s:.1f}s "
            f"(budget {_RECOVERY_BUDGET_S}s)", spans
        )
        assert overhead["disabled_overhead_pct"] < 1.0, (
            f"disabled resilience engine costs "
            f"{overhead['disabled_overhead_pct']:.3f}% of the "
            f"launch-plan replay hot path (gate: < 1%)"
        )
        print(
            f"# smoke ok: {s.launch_retries} launch retries, "
            f"{s.dma_retries} dma retries, {s.quarantined_devices} device "
            f"quarantined, {s.degraded_launches} degraded launch(es) -> "
            f"bit-identical on {n_dev - 1} survivors "
            f"(recovery {recovery_total_s * 1e3:.0f}ms, disabled overhead "
            f"{overhead['disabled_overhead_pct']:.3f}%) -> BENCH_chaos.json"
        )
    return result


def main() -> None:
    import sys

    # --no-header: benchmarks.run already printed the CSV header before
    # re-executing this module in the forced-multi-device subprocess
    if "--no-header" not in sys.argv:
        print("name,us_per_call,derived")
    res = run(smoke="--smoke" in sys.argv)
    if "--smoke" not in sys.argv:
        print(
            f"# chaos: {res['counters']} bit_identical="
            f"{res['bit_identical']} recovery={res['recovery_total_s']:.2f}s"
        )


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    main()
