"""Paper Table 7: lines of code per component (composability evidence).

The paper reports 2363 LoC for its omp->HLS connection, arguing MLIR
composability keeps the new-work surface small. Same accounting here:
the paper-equivalent flow components vs the total framework.
"""

from __future__ import annotations

import os

from .common import emit

ROOT = os.path.join(os.path.dirname(__file__), "..", "src", "repro")

COMPONENTS = {
    "omp_to_tkl_flow (this work's analogue)": [
        "core/passes", "core/dialects/omp.py", "core/dialects/device.py",
        "core/dialects/tkl.py",
    ],
    "tkl_dialect_and_pallas_backend ([20] analogue)": [
        "core/backend/pallas_codegen.py", "core/backend/jnp_ref.py",
    ],
    "runtime_integration ([19] analogue)": [
        "core/runtime.py", "core/backend/host_executor.py",
    ],
    "frontend_lowering ([3] analogue)": [
        "core/frontend", "core/ir.py", "core/dialects/builtins.py",
    ],
    "lm_framework (beyond paper)": [
        "models", "configs", "parallel", "data", "optim", "checkpoint",
        "ft", "launch", "kernels",
    ],
}


def count_loc(rel: str) -> int:
    path = os.path.join(ROOT, rel)
    total = 0
    if os.path.isfile(path):
        files = [path]
    else:
        files = []
        for dirpath, _, names in os.walk(path):
            files += [os.path.join(dirpath, f) for f in names
                      if f.endswith(".py")]
    for f in files:
        with open(f) as fh:
            total += sum(
                1 for line in fh
                if line.strip() and not line.strip().startswith("#")
            )
    return total


def run() -> None:
    for comp, paths in COMPONENTS.items():
        loc = sum(count_loc(p) for p in paths)
        emit(f"loc_{comp.split(' ')[0]}", 0.0, f"loc={loc};{comp}")


if __name__ == "__main__":
    run()
