"""Trace-analytics regression sentry benchmark / CI smoke lane.

Two traced workloads over four forced host devices:

  saxpy-chain — the fused producer→consumer chain (``chain_source``),
                the lane's primary analytics subject;
  teams       — the mesh ``saxpy_teams`` launch, recorded so the
                committed seed baselines cover a multi-device profile.

The lane exercises the whole attribution pipeline end to end:

1. analyze the clean chain trace (`repro.core.obs.analytics`) and gate
   the report's structure — every critical-path span id resolves into
   the trace and survives a Chrome-trace export round-trip, the phase
   breakdown's self times + idle sum to (≤) total wall time, and at
   least one kernel window is roofline-classified;
2. record both profiles into a workspace-local
   :class:`~repro.core.obs.baseline.BaselineStore`
   (``BENCH_sentry_baselines.json``);
3. re-run the same chain under an injected *latency* fault on the H2D
   path (``dma_h2d:latency:...`` via the resilience injector) and
   require ``compare()`` to report a regression whose **responsible
   phase is DMA** — attribution, not just a total-time delta.

A committed seed store (``benchmarks/baselines/sentry_seed.json``)
is validated for shape and diffed report-only: its fingerprint key is
portable across CI runs of this container shape, its timings are not,
so the hard gate always uses the baseline recorded in-run.

Artifacts: ``BENCH_sentry.json``, the rendered analytics report
(``BENCH_sentry_report.txt``), the chain trace
(``repro_trace_sentry.json``), and a refreshed
``BENCH_trajectory.json``.

Run under a forced multi-device host platform:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.bench_sentry [--smoke]

or let the harness set the flag for you:

    PYTHONPATH=src python -m benchmarks.run --smoke sentry
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict

import numpy as np

try:
    from .common import emit, write_json_atomic
    from .bench_obs import validate_chrome_trace
    from .history import emit_trajectory
except ImportError:  # standalone: python benchmarks/bench_sentry.py
    from common import emit, write_json_atomic
    from bench_obs import validate_chrome_trace
    from history import emit_trajectory

import jax

from repro.core import compile_fortran
from repro.core.obs.analytics import analyze, kernel_costs_from_ir
from repro.core.obs.baseline import BaselineStore, device_fingerprint
from repro.core.workloads import chain_source, saxpy_teams_source

_TRACE_JSON = "repro_trace_sentry.json"
_REPORT_TXT = "BENCH_sentry_report.txt"
_STORE_JSON = "BENCH_sentry_baselines.json"
_SEED_STORE = os.path.join(os.path.dirname(__file__), "baselines",
                           "sentry_seed.json")

#: the scripted slowdown the sentry must *attribute*, not just detect:
#: the first four H2D transfers each stall 50 ms — a ~200 ms bump that
#: lands in the DMA phase and nowhere else
_FAULT_PLAN = "dma_h2d:latency:0.05:4"

_EPS = 1e-6


def _chain_prog(n: int, stages: int, fault_plan=None):
    prog = compile_fortran(
        chain_source(stages, n), trace=True, fault_plan=fault_plan
    )
    args = (np.int32(n),) + tuple(
        np.ones(n, np.float32) for _ in range(stages + 1)
    )
    prog.run("chain", args=args)
    return prog


def _structural_gates(rep, doc) -> Dict[str, Any]:
    """The analytics-report structure the lane gates on."""
    n = len(rep.spans)
    ids_ok = (
        bool(rep.critical_path_ids)
        and all(0 <= i < n for i in rep.critical_path_ids)
    )
    # export round-trip: the same critical path must fall out of the
    # serialized trace (span ids are positions in the shared sort)
    rt = analyze(doc)
    key = lambda r: [(r.spans[i].name, r.spans[i].cat)
                     for i in r.critical_path_ids]
    roundtrip_ok = key(rt) == key(rep)
    phase_self = sum(st.self_s for st in rep.phases.values())
    phase_sum_ok = phase_self + rep.idle_s <= rep.wall_s * (1 + _EPS) + _EPS
    classified = [
        name for name, k in rep.kernels.items()
        if k["bound"] in ("compute", "bandwidth")
    ]
    return {
        "critical_path_ids_exist": ids_ok,
        "critical_path_roundtrip": roundtrip_ok,
        "critical_path_spans": len(rep.critical_path_ids),
        "critical_path_s": rep.critical_path_s,
        "phase_self_plus_idle_s": phase_self + rep.idle_s,
        "wall_s": rep.wall_s,
        "phase_sum_bounded": phase_sum_ok,
        "classified_kernels": classified,
    }


def _seed_check(store_cls, workloads, fp, profiles) -> Dict[str, Any]:
    """Shape-validate the committed seed store and diff it report-only
    (timings from another machine never gate)."""
    out: Dict[str, Any] = {"path": _SEED_STORE}
    if not os.path.exists(_SEED_STORE):
        out["status"] = "missing"
        return out
    seed = store_cls(_SEED_STORE)
    out["recovered_corrupt"] = seed.recovered_corrupt
    out["entries"] = sorted(seed.items())
    out["workloads_present"] = {
        w: seed.get(w, fp) is not None for w in workloads
    }
    out["status"] = (
        "ok" if not seed.recovered_corrupt and len(seed) else "invalid"
    )
    out["report_only_compare"] = {
        w: seed.compare(w, fp, profiles[w]) for w in workloads
        if seed.get(w, fp) is not None
    }
    return out


def run(smoke: bool = False) -> Dict[str, Any]:
    n_dev = len(jax.devices())
    n = 4096 if smoke else 16384
    stages = 3

    # -- clean chain run: analyze + gate ---------------------------------
    prog = _chain_prog(n, stages)
    rep = analyze(
        prog.tracer, cost_table=kernel_costs_from_ir(prog.device_module)
    )
    prog.write_trace(_TRACE_JSON)
    doc = json.load(open(_TRACE_JSON))
    validate_chrome_trace(doc)
    gates = _structural_gates(rep, doc)
    with open(_REPORT_TXT, "w") as f:
        f.write(rep.render() + "\n")

    # -- teams run: the multi-device profile -----------------------------
    rng = np.random.default_rng(0)
    x = rng.normal(size=n).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    teams = compile_fortran(saxpy_teams_source(n), trace=True)
    teams.run("saxpy", args=(np.int32(n), np.float32(2.5), x, y.copy()))
    rep_teams = analyze(
        teams.tracer, cost_table=kernel_costs_from_ir(teams.device_module)
    )

    # -- record baselines (fresh in-run store) ---------------------------
    try:
        os.unlink(_STORE_JSON)
    except FileNotFoundError:
        pass
    fp = device_fingerprint()
    store = BaselineStore(_STORE_JSON)
    profiles = {"saxpy-chain": rep.profile(), "teams": rep_teams.profile()}
    for w, p in profiles.items():
        store.put(w, fp, p, meta={"lane": "sentry", "n": n})

    # -- faulted chain run: the slowdown must be *attributed* ------------
    faulted = _chain_prog(n, stages, fault_plan=_FAULT_PLAN)
    rep_faulted = analyze(
        faulted.tracer,
        cost_table=kernel_costs_from_ir(faulted.device_module),
    )
    verdict = store.compare("saxpy-chain", fp, rep_faulted.profile())
    faults = faulted.executor().resilience.injector.snapshot()

    dma_clean = rep.phases["dma"].self_s
    dma_faulted = rep_faulted.phases["dma"].self_s
    emit(
        "sentry/chain_analytics", rep.wall_s * 1e6,
        f"critical_path_spans={gates['critical_path_spans']} "
        f"classified={','.join(gates['classified_kernels'])} "
        f"idle_pct={rep.idle_s / max(rep.wall_s, 1e-12) * 100:.1f}",
    )
    emit(
        "sentry/dma_attribution", (dma_faulted - dma_clean) * 1e6,
        f"plan={_FAULT_PLAN!r} status={verdict['status']} "
        f"responsible_phase={verdict.get('responsible_phase')}",
    )

    seed = _seed_check(
        BaselineStore, list(profiles), fp, profiles
    )
    result: Dict[str, Any] = {
        "n": n,
        "stages": stages,
        "devices": n_dev,
        "device_fp": fp,
        "fault_plan": _FAULT_PLAN,
        "gates": gates,
        "clean_profile": profiles["saxpy-chain"],
        "teams_profile": profiles["teams"],
        "faulted_profile": rep_faulted.profile(),
        "compare": verdict,
        "dma_self_clean_s": dma_clean,
        "dma_self_faulted_s": dma_faulted,
        "faults": faults,
        "seed_baselines": seed,
        "baseline_store": _STORE_JSON,
        "trace_artifact": _TRACE_JSON,
        "report_artifact": _REPORT_TXT,
    }
    write_json_atomic("BENCH_sentry.json", result)
    trajectory = emit_trajectory()
    result["trajectory_artifact"] = trajectory

    if smoke:
        assert n_dev > 1, (
            f"sentry smoke needs >1 device (run via `benchmarks.run "
            f"--smoke sentry` or set XLA_FLAGS); got {n_dev}"
        )
        assert gates["critical_path_ids_exist"], gates
        assert gates["critical_path_roundtrip"], gates
        assert gates["phase_sum_bounded"], gates
        assert gates["classified_kernels"], (
            "no kernel window was roofline-classified", rep.kernels,
        )
        assert faults.get("fired", {}).get("dma_h2d", 0) > 0, faults
        assert verdict["status"] == "regression", verdict
        assert verdict["responsible_phase"] == "dma", (
            "injected dma_h2d latency was not attributed to the DMA "
            "phase", verdict,
        )
        print(
            f"# smoke ok: critical path "
            f"{gates['critical_path_spans']} span(s) / "
            f"{gates['critical_path_s'] * 1e3:.1f}ms, "
            f"{len(gates['classified_kernels'])} kernel(s) classified, "
            f"dma phase {dma_clean * 1e3:.1f}ms -> "
            f"{dma_faulted * 1e3:.1f}ms under {_FAULT_PLAN!r}, "
            f"responsible_phase={verdict['responsible_phase']} -> "
            f"BENCH_sentry.json"
        )
    return result


def main() -> None:
    import sys

    # --no-header: benchmarks.run already printed the CSV header before
    # re-executing this module in the forced-multi-device subprocess
    if "--no-header" not in sys.argv:
        print("name,us_per_call,derived")
    res = run(smoke="--smoke" in sys.argv)
    if "--smoke" not in sys.argv:
        print(
            f"# sentry: compare={res['compare']['status']} "
            f"responsible_phase={res['compare'].get('responsible_phase')} "
            f"dma {res['dma_self_clean_s'] * 1e3:.1f}ms -> "
            f"{res['dma_self_faulted_s'] * 1e3:.1f}ms"
        )


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    main()
