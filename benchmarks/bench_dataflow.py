"""VMEM-resident dataflow benchmark.

The same k-stage producer→consumer chain as ``bench_fusion``, compiled
three ways:

  unfused  — one kernel triple + full DMA round trip per stage;
  chained  — all stages fused into one kernel (PR 2), but compiled as a
             chain of per-stage ``pallas_call``s with HBM arrays
             threaded between them;
  dataflow — one single ``pallas_call``: stage bodies run back-to-back
             on the same VMEM block, stream-carried intermediates never
             round-trip through HBM between stages (the paper's HLS
             dataflow/stream-FIFO optimisation, TPU-adapted).

Also reports the executor-side dataflow counters and the launch-plan
hit rate of a repeated run.

    PYTHONPATH=src python -m benchmarks.run dataflow
    PYTHONPATH=src python -m benchmarks.run --smoke   # tiny shapes,
        asserts counters + the speedup sign vs the chained schedule and
        writes BENCH_dataflow.json
"""

from __future__ import annotations

import json
import time
from typing import Dict

import numpy as np

try:
    from .common import emit, write_json_atomic
except ImportError:  # standalone: python benchmarks/bench_dataflow.py
    from common import emit, write_json_atomic

from repro.core import compile_fortran
from repro.core.runtime import DeviceDataEnvironment
from repro.core.workloads import chain_source


def _bench(prog, args_fn, iters: int) -> float:
    times = []
    for _ in range(iters + 1):  # first pass warms the jit caches
        a = args_fn()
        t0 = time.perf_counter()
        prog.run("chain", args=a)
        times.append(time.perf_counter() - t0)
    return float(np.median(times[1:]))


def run(smoke: bool = False) -> Dict[str, float]:
    stages = 4 if smoke else 6
    n = 4096 if smoke else 8192
    iters = 3 if smoke else 5
    src = chain_source(stages, n)

    dataflow = compile_fortran(src)
    chained = compile_fortran(src, dataflow=False)
    unfused = compile_fortran(src, fuse=False, eliminate_transfers=False)

    rng = np.random.default_rng(0)
    bufs = [rng.normal(size=n).astype(np.float32) for _ in range(stages + 1)]

    def args_fn():
        return tuple([np.int32(n)] + [b.copy() for b in bufs])

    # the dataflow schedule must be bit-identical to both fallbacks
    out_d = dataflow.run("chain", args=args_fn())
    out_c = chained.run("chain", args=args_fn())
    out_u = unfused.run("chain", args=args_fn())
    for j in range(stages + 1):
        assert np.array_equal(
            np.asarray(out_d[f"s{j}"]), np.asarray(out_c[f"s{j}"])
        ), f"dataflow changed s{j} vs chained"
        assert np.array_equal(
            np.asarray(out_d[f"s{j}"]), np.asarray(out_u[f"s{j}"])
        ), f"dataflow changed s{j} vs unfused"

    # deterministic counters: one pallas_call, stages-1 streams carried
    env = DeviceDataEnvironment()
    dataflow.run("chain", args=args_fn(), env=env)
    df_kernels = env.stats.dataflow_kernels
    streams = env.stats.streams_carried
    rt_elim = env.stats.hbm_round_trips_eliminated
    ex = dataflow.executor()
    (kname,) = ex.kernels
    n_calls = ex.kernels[kname].n_pallas_calls

    t_unfused = _bench(unfused, args_fn, iters)
    t_chained = _bench(chained, args_fn, iters)
    t_dataflow = _bench(dataflow, args_fn, iters)
    retries = 2
    while smoke and t_dataflow >= t_chained and retries > 0:
        # CI gates on the speedup sign; absorb shared-runner noise before
        # declaring a regression — the counters above are the primary
        # gate, this protects against a genuine wall-clock loss only.
        t_chained = min(t_chained, _bench(chained, args_fn, iters))
        t_dataflow = min(t_dataflow, _bench(dataflow, args_fn, iters))
        retries -= 1
    speedup_vs_chained = t_chained / max(t_dataflow, 1e-12)
    speedup_vs_unfused = t_unfused / max(t_dataflow, 1e-12)

    # launch plans: a second run over the same executor replays the
    # precompiled instruction lists (no rebuilds)
    builds = env.stats.launch_plan_builds
    dataflow.run("chain", args=args_fn(), env=env)
    plan_hits = env.stats.launch_plan_hits

    emit("dataflow/unfused", t_unfused * 1e6, f"stages={stages} n={n}")
    emit(
        "dataflow/chained",
        t_chained * 1e6,
        f"pallas_calls_per_run={stages}",
    )
    emit(
        "dataflow/single_call",
        t_dataflow * 1e6,
        f"speedup_vs_chained={speedup_vs_chained:.2f}x "
        f"pallas_calls_per_run={n_calls} "
        f"streams={streams} "
        f"hbm_round_trips_eliminated={rt_elim}",
    )
    emit(
        "dataflow/launch_plans", 0.0,
        f"builds={builds} replay_hits={plan_hits}",
    )

    result = {
        "stages": stages,
        "n": n,
        "unfused_us": t_unfused * 1e6,
        "chained_us": t_chained * 1e6,
        "dataflow_us": t_dataflow * 1e6,
        "speedup_vs_chained": speedup_vs_chained,
        "speedup_vs_unfused": speedup_vs_unfused,
        "pallas_calls_per_run": n_calls,
        "dataflow_kernels": df_kernels,
        "streams_carried": streams,
        "hbm_round_trips_eliminated": rt_elim,
        "launch_plan_builds": builds,
        "launch_plan_hits": plan_hits,
    }
    if smoke:
        write_json_atomic("BENCH_dataflow.json", result)
        # deterministic counters first, then the (noise-retried) sign
        assert n_calls == 1, f"expected one pallas_call, got {n_calls}"
        assert df_kernels > 0, result
        assert rt_elim > 0, result
        assert speedup_vs_chained > 1.0, (
            f"dataflow slower than chained: {speedup_vs_chained:.2f}x"
        )
        print(
            f"# smoke ok: dataflow {speedup_vs_chained:.2f}x vs chained, "
            f"{rt_elim} HBM round trips eliminated -> BENCH_dataflow.json"
        )
    return result


def main() -> None:
    import sys

    # --no-header / --smoke: benchmarks.run dispatches every smoke lane
    # through the shared subprocess helper after printing the CSV header
    if "--no-header" not in sys.argv:
        print("name,us_per_call,derived")
    res = run(smoke="--smoke" in sys.argv)
    if "--smoke" not in sys.argv:
        print(
            f"# single-call dataflow {res['speedup_vs_chained']:.2f}x over "
            f"chained (target >= 1.3x), {res['speedup_vs_unfused']:.2f}x over "
            "unfused"
        )


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    main()
