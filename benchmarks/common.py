"""Shared benchmark helpers."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import numpy as np


def write_json_atomic(path: str, payload: Any, indent: int = 2) -> str:
    """Write a ``BENCH_*.json`` artifact atomically: temp file in the
    target's directory + ``os.replace``, so CI collecting artifacts (or
    a crashed lane) never sees a truncated file."""
    dirname = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(
        prefix=".bench-", suffix=".json.tmp", dir=dirname
    )
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=indent)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def reexec_lane(
    module: str,
    args: Sequence[str] = (),
    env_updates: Optional[Dict[str, str]] = None,
    force_host_devices: int = 0,
) -> None:
    """Run ``python -m <module> <args>`` as a subprocess lane.

    The one re-exec/env-flag recipe every smoke lane shares: some lanes
    need process isolation jax cannot provide in-process —
    ``force_host_devices`` injects
    ``--xla_force_host_platform_device_count=N`` into ``XLA_FLAGS``
    (read at jax import, so the parent may already be pinned), and
    ``env_updates`` seeds lane-specific state such as a fresh tuning
    store.  stdout/stderr stream through; a failing lane propagates its
    exit code as :class:`SystemExit`.
    """
    env = dict(os.environ)
    if force_host_devices:
        flag = (
            f"--xla_force_host_platform_device_count={force_host_devices}"
        )
        flags = env.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            env["XLA_FLAGS"] = (flags + " " + flag).strip()
    if env_updates:
        env.update(env_updates)
    sys.stdout.flush()
    proc = subprocess.run(
        [sys.executable, "-m", module, *args], env=env
    )
    if proc.returncode != 0:
        raise SystemExit(proc.returncode)


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            **kw) -> Tuple[float, float]:
    """Median and std wall time (seconds) of fn(*args)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), float(np.std(ts))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """CSV row per the harness contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def percentiles(
    seconds: Sequence[float], quantiles: Sequence[float] = (0.5, 0.95, 0.99)
) -> Dict[str, float]:
    """Latency distribution of a sample of wall times (seconds) as the
    ``{"count": N, "p50_us": ..., "p95_us": ..., "p99_us": ...}`` dict
    every ``BENCH_*.json`` lane embeds — nearest-rank, matching the
    metrics registry's :class:`~repro.core.obs.Histogram` quantiles."""
    data = sorted(float(t) for t in seconds)
    out: Dict[str, float] = {"count": float(len(data))}
    for q in quantiles:
        if data:
            idx = min(len(data) - 1, max(0, round(q * (len(data) - 1))))
            out[f"p{q * 100:g}_us"] = data[int(idx)] * 1e6
        else:
            out[f"p{q * 100:g}_us"] = float("nan")
    return out
