"""Shared benchmark helpers."""

from __future__ import annotations

import time
from typing import Callable, Tuple

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            **kw) -> Tuple[float, float]:
    """Median and std wall time (seconds) of fn(*args)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), float(np.std(ts))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """CSV row per the harness contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)
