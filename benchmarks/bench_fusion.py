"""Target-region fusion / redundant-transfer elimination / compile-cache
benchmark.

A k-stage producer→consumer chain (saxpy→saxpy→…, the sgesl-style
update pattern) compiled three ways:

  unfused — the paper's per-region lowering: one kernel triple and a
            full map prologue/epilogue (DMA round trip) per stage;
  rte     — per-region kernels, but redundant copy-back/copy-in pairs
            statically eliminated;
  fused   — all stages merged into one kernel by target-region fusion
            (one dispatch, one prologue/epilogue set).

Also measures kernel-compile time for a second HostExecutor over the
same module: the structural compile cache should make it near zero with
a 100% hit rate.

    PYTHONPATH=src python -m benchmarks.run fusion
    PYTHONPATH=src python -m benchmarks.run --smoke     # tiny shapes,
        asserts the speedup sign and writes BENCH_fusion.json
"""

from __future__ import annotations

import json
import time
from typing import Dict

import numpy as np

try:
    from .common import emit, write_json_atomic
except ImportError:  # standalone: python benchmarks/bench_fusion.py
    from common import emit, write_json_atomic

from repro.core import compile_fortran
from repro.core.backend.host_executor import HostExecutor, clear_kernel_cache
from repro.core.runtime import DeviceDataEnvironment
from repro.core.workloads import chain_source


def _bench(prog, args_fn, iters: int) -> float:
    times = []
    for _ in range(iters + 1):  # first pass warms the jit caches
        a = args_fn()
        t0 = time.perf_counter()
        prog.run("chain", args=a)
        times.append(time.perf_counter() - t0)
    return float(np.median(times[1:]))


def run(smoke: bool = False) -> Dict[str, float]:
    stages = 4 if smoke else 6
    n = 4096 if smoke else 8192
    iters = 3 if smoke else 5
    src = chain_source(stages, n)

    fused = compile_fortran(src)
    rte = compile_fortran(src, fuse=False, eliminate_transfers=True)
    unfused = compile_fortran(src, fuse=False, eliminate_transfers=False)

    rng = np.random.default_rng(0)
    bufs = [rng.normal(size=n).astype(np.float32) for _ in range(stages + 1)]

    def args_fn():
        return tuple([np.int32(n)] + [b.copy() for b in bufs])

    # fusion must be semantics-preserving: bit-identical outputs
    out_f = fused.run("chain", args=args_fn())
    out_u = unfused.run("chain", args=args_fn())
    for j in range(stages + 1):
        assert np.array_equal(
            np.asarray(out_f[f"s{j}"]), np.asarray(out_u[f"s{j}"])
        ), f"fusion changed s{j}"

    t_unfused = _bench(unfused, args_fn, iters)
    t_rte = _bench(rte, args_fn, iters)
    t_fused = _bench(fused, args_fn, iters)
    retries = 2
    while smoke and t_fused >= t_unfused and retries > 0:
        # The smoke lane gates CI on the speedup sign; absorb noisy
        # measurements (shared CI runners) before declaring a
        # regression — the deterministic counters below are the primary
        # gate, this protects only against a genuine wall-clock loss.
        t_unfused = min(t_unfused, _bench(unfused, args_fn, iters))
        t_fused = min(t_fused, _bench(fused, args_fn, iters))
        retries -= 1
    speedup = t_unfused / max(t_fused, 1e-12)
    rte_speedup = t_unfused / max(t_rte, 1e-12)

    stats = fused.optimize_stats
    emit("fusion/unfused", t_unfused * 1e6, f"stages={stages} n={n}")
    emit("fusion/rte", t_rte * 1e6, f"speedup={rte_speedup:.2f}x")
    emit(
        "fusion/fused",
        t_fused * 1e6,
        f"speedup={speedup:.2f}x fused_regions={stats['fused_regions']} "
        f"transfers_eliminated={stats['transfers_eliminated']}",
    )

    # -- compile cache: second executor over the same module --------------
    clear_kernel_cache()
    t0 = time.perf_counter()
    e1 = HostExecutor(fused.host_module, fused.device_module,
                      env=DeviceDataEnvironment())
    for k in e1.kernels:
        e1.kernels[k]
    t_cold = time.perf_counter() - t0

    t0 = time.perf_counter()
    e2 = HostExecutor(fused.host_module, fused.device_module,
                      env=DeviceDataEnvironment())
    for k in e2.kernels:
        e2.kernels[k]
    t_warm = time.perf_counter() - t0
    s2 = e2.device_env.stats
    total = s2.kernel_cache_hits + s2.kernel_cache_misses
    hit_rate = s2.kernel_cache_hits / max(1, total)
    emit("fusion/compile_cold", t_cold * 1e6, "first executor")
    emit(
        "fusion/compile_warm",
        t_warm * 1e6,
        f"hit_rate={hit_rate:.0%} recompile_ratio={t_warm / max(t_cold, 1e-12):.3f}",
    )

    result = {
        "stages": stages,
        "n": n,
        "unfused_us": t_unfused * 1e6,
        "rte_us": t_rte * 1e6,
        "fused_us": t_fused * 1e6,
        "speedup": speedup,
        "rte_speedup": rte_speedup,
        "fused_regions": stats["fused_regions"],
        "transfers_eliminated": stats["transfers_eliminated"],
        "compile_cold_us": t_cold * 1e6,
        "compile_warm_us": t_warm * 1e6,
        "cache_hit_rate": hit_rate,
    }
    if smoke:
        write_json_atomic("BENCH_fusion.json", result)
        # deterministic compile-time counters first, then the (noise-
        # retried) wall-clock sign
        assert stats["fused_regions"] == stages - 1, stats
        assert stats["transfers_eliminated"] > 0, stats
        assert speedup > 1.0, f"fusion slower than unfused: {speedup:.2f}x"
        assert hit_rate == 1.0, f"compile cache missed: {hit_rate:.0%}"
        print(f"# smoke ok: fused {speedup:.2f}x, cache hit rate "
              f"{hit_rate:.0%} -> BENCH_fusion.json")
    return result


def main() -> None:
    import sys

    # --no-header / --smoke: benchmarks.run dispatches every smoke lane
    # through the shared subprocess helper after printing the CSV header
    if "--no-header" not in sys.argv:
        print("name,us_per_call,derived")
    res = run(smoke="--smoke" in sys.argv)
    if "--smoke" not in sys.argv:
        print(f"# fused speedup over unfused: {res['speedup']:.2f}x "
              f"(target >= 1.5x), warm recompile {res['compile_warm_us']:.0f}us")


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    main()
